"""Distributed substrate: checkpoint/restart, fault injection + replay
determinism, straggler detection, gradient compression, reader-partitioned
EAGr shards (per-shard host loop AND the stacked shard_map engine)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_freqs
from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.dynamic import DynamicOverlay
from repro.core.engine import EagrEngine, compile_plan
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.compression import (
    compress_with_feedback,
    dequantize_int8,
    init_error_state,
    quantize_int8,
)
from repro.distributed.eagr_shard import (
    ShardedDynamic,
    host_loop_read,
    host_loop_write,
    partition_overlay,
    shard_read_batch,
)
from repro.distributed.stacked import (
    StackedShardedEngine,
    _stacked_read,
    _stacked_write_sum,
)
from repro.distributed.fault import FaultTolerantRunner, StragglerDetector
from repro.graphs.generators import rmat_graph
from repro.train.optimizer import get_optimizer
from repro.train.trainer import make_train_step


# ------------------------------------------------------------- checkpointing
def _toy_state(key=0):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (8, 4)),
            "b": jnp.zeros((4,)),
            "opt": {"mu": jnp.ones((8, 4)), "count": jnp.int32(3)}}


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    state = _toy_state()
    cm.save(10, state)
    restored, manifest = cm.restore(state)
    assert manifest["step"] == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    state = _toy_state()
    for s in (1, 2, 3, 4):
        cm.save(s, state)
    assert cm.all_steps() == [3, 4]
    assert cm.latest_step() == 4


def test_checkpoint_async_and_atomicity(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    state = _toy_state()
    cm.save(7, state, blocking=False)
    cm.wait()
    assert cm.latest_step() == 7
    # a stale .tmp dir (crash mid-write) must be invisible
    import os
    os.makedirs(tmp_path / "step_00000099.tmp")
    assert cm.latest_step() == 7


def test_checkpoint_restore_with_resharding(tmp_path):
    """Restore under a different sharding (elastic restart)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cm = CheckpointManager(str(tmp_path))
    state = _toy_state()
    cm.save(1, state)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), state)
    restored, _ = cm.restore(state, shardings=sh)
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(state["w"]))


# ------------------------------------------------------- fault-tolerant loop
def test_fault_runner_replays_deterministically(tmp_path):
    """Training with injected failures must converge to the exact same state
    as an uninterrupted run (checkpoint + deterministic data replay)."""
    opt = get_optimizer("sgd")

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    step = make_train_step(loss_fn, opt, clip_norm=None)

    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, metrics = step(params, opt_state, batch, 0.05)
        return (params, opt_state), metrics

    def make_batch(i):
        k = jax.random.PRNGKey(i)
        x = jax.random.normal(k, (16, 4))
        return {"x": x, "y": x @ jnp.arange(4.0)[:, None]}

    params0 = {"w": jnp.zeros((4, 1))}
    state0 = (params0, opt.init(params0))

    cm1 = CheckpointManager(str(tmp_path / "a"))
    r1 = FaultTolerantRunner(step_fn, make_batch, cm1, ckpt_every=5)
    clean, rep1 = r1.run(state0, 30)
    assert rep1.restarts == 0

    cm2 = CheckpointManager(str(tmp_path / "b"))
    r2 = FaultTolerantRunner(step_fn, make_batch, cm2, ckpt_every=5)
    faulty, rep2 = r2.run(state0, 30, fail_at={12, 23})
    assert rep2.restarts == 2
    np.testing.assert_allclose(np.asarray(clean[0]["w"]),
                               np.asarray(faulty[0]["w"]), rtol=1e-6)


def test_straggler_detector():
    det = StragglerDetector(z=4.0)
    for i in range(20):
        det.observe(i, 0.10 + 0.001 * (i % 3))
    assert det.observe(20, 0.5)        # 5x median
    assert not det.observe(21, 0.101)


# ---------------------------------------------------------------- compression
def test_quantize_roundtrip_error_bound():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(256,)).astype(np.float32))
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) / 2 + 1e-7


def test_error_feedback_unbiased_over_steps():
    """With error feedback, the SUM of compressed grads tracks the sum of true
    grads (residual stays bounded)."""
    rng = np.random.default_rng(1)
    g_true = [jnp.asarray(rng.normal(size=(64,)).astype(np.float32) * 0.01)
              for _ in range(50)]
    err = init_error_state({"g": g_true[0]})
    acc_c = jnp.zeros(64)
    for g in g_true:
        cg, err = compress_with_feedback({"g": g}, err)
        acc_c = acc_c + cg["g"]
    acc_t = sum(g_true[1:], g_true[0])
    resid = float(jnp.abs(acc_c - acc_t).max())
    # residual equals the last carried error, bounded by one quantization step
    assert resid <= float(jnp.abs(err["g"]).max()) + 1e-6


def test_compressed_training_converges():
    opt = get_optimizer("sgd", momentum=0.0)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (128, 8))
    w_true = jnp.arange(8.0)[:, None] / 4
    y = x @ w_true
    params = {"w": jnp.zeros((8, 1))}
    err = init_error_state(params)
    opt_state = opt.init(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.mean((x @ p["w"] - y) ** 2))(params)
        cg, err = compress_with_feedback(g, err)
        params, opt_state = opt.update(cg, opt_state, params, 0.05)
    assert float(jnp.abs(params["w"] - w_true).max()) < 1e-2


# ------------------------------------------------------------ EAGr sharding
def _eagr_sharded_system(n=200, e=1200, seed=9, n_shards=4, part_seed=0,
                         headroom=None):
    g = rmat_graph(n, e, seed=seed)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
    wf, rf = make_freqs(g.n_nodes, seed=seed)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
    sharded = partition_overlay(ov, dec, n_shards=n_shards, seed=part_seed,
                                headroom=headroom)
    return g, bp, ov, dec, sharded


def test_reader_partitioned_shards_match_global_engine():
    g, bp, ov, dec, sharded = _eagr_sharded_system()
    agg = make_aggregate("sum")
    spec = WindowSpec("tuple", 4)

    global_eng = EagrEngine(ov, dec, agg, spec)
    assert sharded.replication_factor() >= 1.0
    engines = [EagrEngine(s, d, agg, spec, plan=p)
               for s, d, p in zip(sharded.shards, sharded.shard_decisions,
                                  sharded.shard_plans)]

    rng = np.random.default_rng(10)
    ris = bp.reader_input_sets()
    for _ in range(4):
        ids = rng.choice(bp.writers, 64)
        vals = rng.normal(size=64).astype(np.float32)
        global_eng.write_batch(ids, vals)
        host_loop_write(sharded, engines, ids, vals)

    readers = rng.choice(list(ris.keys()), 24)
    want = np.ravel(global_eng.read_batch(readers))
    got = np.ravel(host_loop_read(sharded, engines, readers))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_shard_partition_covers_all_readers():
    g = rmat_graph(150, 900, seed=12)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=2, seed=0)
    wf, rf = make_freqs(g.n_nodes, seed=12)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
    sharded = partition_overlay(ov, dec, n_shards=3, seed=1)
    all_readers = {ov.origin[r] for r in ov.reader_nodes()}
    assert set(sharded.reader_shard.keys()) == all_readers
    for s, eng_ov in enumerate(sharded.shards):
        eng_ov.toposort()  # each shard closure is a valid DAG


# --------------------------------------------------- stacked shard_map engine
def test_stacked_engine_bit_identical_to_host_loop():
    """One shard_map/vmap program over the stacked plans must equal the
    per-shard host loop lane for lane — same bodies, same masked layout."""
    g, bp, ov, dec, sharded = _eagr_sharded_system()
    agg = make_aggregate("sum")
    spec = WindowSpec("tuple", 4)
    stacked = StackedShardedEngine(sharded, agg, spec)
    engines = [EagrEngine(s, d, agg, spec, plan=p)
               for s, d, p in zip(sharded.shards, sharded.shard_decisions,
                                  sharded.shard_plans)]
    rng = np.random.default_rng(10)
    ris = bp.reader_input_sets()
    for _ in range(4):
        ids = rng.choice(bp.writers, 64)
        vals = rng.normal(size=64).astype(np.float32)
        stacked.write_batch(ids, vals, batch_size=64)
        host_loop_write(sharded, engines, ids, vals)

    readers = rng.choice(list(ris.keys()), 24)
    want = host_loop_read(sharded, engines, readers)
    got = stacked.read_batch(readers, batch_size=24)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_stacked_engine_extremal_matches_global():
    g, bp, ov, dec, sharded = _eagr_sharded_system(seed=11)
    agg = make_aggregate("max")
    spec = WindowSpec("tuple", 3)
    global_eng = EagrEngine(ov, dec, agg, spec)
    stacked = StackedShardedEngine(sharded, agg, spec)
    rng = np.random.default_rng(2)
    ris = bp.reader_input_sets()
    for _ in range(3):
        ids = rng.choice(bp.writers, 48)
        vals = rng.normal(size=48).astype(np.float32)
        global_eng.write_batch(ids, vals, batch_size=48)
        stacked.write_batch(ids, vals, batch_size=48)
    readers = rng.choice(list(ris.keys()), 16)
    want = np.ravel(global_eng.read_batch(readers, batch_size=16))
    got = np.ravel(stacked.read_batch(readers, batch_size=16))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def _stacked_oracle_read(stacked, sd, r):
    """Ground truth straight from the owning shard's writer windows — the
    single-engine ``oracle_read`` applied to the stacked deployment. (The
    global engine is NOT the oracle under churn: a newly subscribed shard
    starts the writer's window empty — the documented backfill gap.)"""
    from repro.core.window import window_pao, window_shard

    s = stacked.sharded.reader_shard[int(r)]
    plan = stacked.sharded.shard_plans[s]
    win = window_shard(stacked.state.windows, s)
    wp = np.asarray(jax.device_get(
        window_pao(win, stacked.spec, stacked.agg,
                   now=stacked.state.now[s])))
    count = np.asarray(jax.device_get(win.count))
    acc = stacked.agg.INITIALIZE()
    for w in sd.dynamics[s].reader_inputs[int(r)]:
        row = plan.writer_row_of_base[w]
        if not count[row]:
            continue
        if stacked.agg.combine == "sum":
            acc = acc + wp[row]
        elif stacked.agg.combine == "max":
            acc = np.maximum(acc, wp[row])
        else:
            acc = np.minimum(acc, wp[row])
    return stacked.agg.FINALIZE(acc)


def test_stacked_single_program_under_churn():
    """N-shard execution compiles exactly ONE write and ONE read program, and
    in-capacity structural churn through ShardedDynamic keeps both traces
    (the stacked analogue of test_plan_patch's zero-retrace invariant)."""
    g, bp, ov, dec, sharded = _eagr_sharded_system(n=150, e=900, seed=3,
                                                   headroom=2.0)
    agg = make_aggregate("sum")
    spec = WindowSpec("tuple", 4)
    stacked = StackedShardedEngine(sharded, agg, spec, base_capacity=2048)
    geng = EagrEngine(ov, dec, agg, spec)
    gdyn = DynamicOverlay.from_overlay(ov, bp.reader_input_sets())
    # rebase the global engine onto the unpruned export so deltas align
    ov0 = gdyn.to_overlay(prune=False)
    geng = EagrEngine(ov0, geng.plan.decision, agg, spec, headroom=2.0)

    rng = np.random.default_rng(1)
    ris = bp.reader_input_sets()
    readers = np.array(list(ris))

    def both_write():
        ids = rng.choice(bp.writers, 64)
        vals = rng.normal(size=64).astype(np.float32)
        stacked.write_batch(ids, vals, batch_size=64)
        geng.write_batch(ids, vals, batch_size=64)

    both_write()
    stacked.read_batch(rng.choice(readers, 16), batch_size=16)
    w0, r0 = _stacked_write_sum._cache_size(), _stacked_read._cache_size()

    sd = ShardedDynamic(sharded, stacked)
    recompiles = 0
    for _ in range(10):
        u, r = int(rng.integers(0, 150)), int(rng.choice(list(ris)))
        sd.add_edge(u, r)
        gdyn.add_edge(u, r)
        res = sd.apply()
        geng.apply_delta(gdyn.drain_delta())
        recompiles += sum(bool(x and x.recompiled) for x in res)
        both_write()
    assert recompiles == 0, "headroom churn must patch in place"
    q = rng.choice(readers, 16)
    got = np.ravel(stacked.read_batch(q, batch_size=16))
    want = np.array([np.ravel(_stacked_oracle_read(stacked, sd, r))
                     for r in q]).ravel()
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert _stacked_write_sum._cache_size() == w0, \
        "stacked write retraced under in-capacity churn"
    assert _stacked_read._cache_size() == r0, \
        "stacked read retraced under in-capacity churn"


def test_stacked_time_window_expiry_survives_slice_patch():
    """A slice patch refreshes ONE shard's PAOs; the sibling shards' expiry
    recompute windows must survive — their next extremal write still has to
    notice entries that expired since THEIR last evaluation (regression:
    a shared last-eval clock made every other shard skip the expiry sweep
    and serve stale time-window aggregates)."""
    g, bp, ov, dec, sharded = _eagr_sharded_system(n=150, e=900, seed=3,
                                                   headroom=2.0)
    agg = make_aggregate("max")
    spec = WindowSpec("time", 2.0, capacity=8)
    stacked = StackedShardedEngine(sharded, agg, spec, base_capacity=2048)
    sd = ShardedDynamic(sharded, stacked)
    rng = np.random.default_rng(0)
    readers = np.array(list(bp.reader_input_sets()))

    ids = np.asarray(bp.writers)
    stacked.write_batch(ids, np.full(len(ids), 100.0, np.float32),
                        batch_size=len(ids))                      # t = 0
    empty = np.zeros(0, np.int64)
    for _ in range(2):                                            # t = 1, 2
        stacked.write_batch(empty, np.zeros(0, np.float32), batch_size=4)
    # in-capacity patch on shard 0 only (a reader shard 0 owns)
    r0 = next(r for r, s in sharded.reader_shard.items() if s == 0)
    sd.add_edge(int(rng.integers(0, 150)), int(r0))
    res = sd.apply()
    assert not any(bool(x and x.recompiled) for x in res)
    # next evaluation instant: every t=0 entry is outside the window now,
    # on EVERY shard — not just the patched one
    stacked.write_batch(empty, np.zeros(0, np.float32), batch_size=4)  # t = 3
    q = readers[:16]
    got = np.ravel(stacked.read_batch(q, batch_size=16))
    want = np.array([np.ravel(_stacked_oracle_read(stacked, sd, r))
                     for r in q]).ravel()
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_stacked_growth_fallback_realigns_whole_stack():
    """A capacity overflow on ONE shard recompiles it with growth headroom;
    the stack realigns every sibling to the new padded dims and restacks —
    reads stay exact against the single-engine oracle."""
    g, bp, ov, dec, sharded = _eagr_sharded_system(n=150, e=900, seed=3)
    agg = make_aggregate("sum")
    spec = WindowSpec("tuple", 4)
    stacked = StackedShardedEngine(sharded, agg, spec, base_capacity=4096)
    gdyn = DynamicOverlay.from_overlay(ov, bp.reader_input_sets())
    ov0 = gdyn.to_overlay(prune=False)
    geng = EagrEngine(ov0, dec, agg, spec, headroom=4.0)

    rng = np.random.default_rng(7)
    ris = bp.reader_input_sets()
    readers = np.array(list(ris))
    meta_before = stacked.meta

    ids = rng.choice(bp.writers, 64)
    vals = rng.normal(size=64).astype(np.float32)
    stacked.write_batch(ids, vals, batch_size=64)
    geng.write_batch(ids, vals, batch_size=64)

    sd = ShardedDynamic(sharded, stacked)
    recompiled = False
    for k in range(80):
        nid = 1000 + k
        ins = {int(x) for x in rng.integers(0, 150, 3)}
        outs = {int(rng.choice(list(ris)))}
        sd.add_node(nid, in_neighbors=ins, out_readers=outs)
        gdyn.add_node(nid, in_neighbors=ins, out_readers=outs)
        res = sd.apply()
        geng.apply_delta(gdyn.drain_delta())
        recompiled = recompiled or any(bool(x and x.recompiled) for x in res)
        if recompiled:
            break
    assert recompiled, "node burst should overflow a zero-headroom stack"
    # the whole stack realigned onto one (new) program shape
    assert len({p.meta for p in sharded.shard_plans}) == 1
    assert stacked.meta == sharded.shard_plans[0].meta
    assert stacked.meta != meta_before

    ids = rng.choice(bp.writers, 64)
    vals = rng.normal(size=64).astype(np.float32)
    stacked.write_batch(ids, vals, batch_size=64)
    geng.write_batch(ids, vals, batch_size=64)
    q = rng.choice(readers, 16)
    np.testing.assert_allclose(
        np.ravel(stacked.read_batch(q, batch_size=16)),
        np.ravel(geng.read_batch(q, batch_size=16)), rtol=1e-4, atol=1e-4)


def test_shard_read_batch_unknown_base_id_raises():
    g, bp, ov, dec, sharded = _eagr_sharded_system(n=150, e=900, seed=12,
                                                   n_shards=3, part_seed=1)
    known = next(iter(sharded.reader_shard))
    with pytest.raises(ValueError, match="999983"):
        shard_read_batch(sharded, np.array([known, 999983]))
    agg = make_aggregate("sum")
    stacked = StackedShardedEngine(sharded, agg, WindowSpec("tuple", 4))
    with pytest.raises(ValueError, match="999983"):
        stacked.read_batch(np.array([known, 999983]))


def test_sharded_dynamic_routing_unknown_reader_raises():
    g, bp, ov, dec, sharded = _eagr_sharded_system(n=150, e=900, seed=12,
                                                   n_shards=3, part_seed=1)
    sd = ShardedDynamic(sharded)
    with pytest.raises(ValueError, match="999983"):
        sd.add_edge(3, 999983)
    with pytest.raises(ValueError, match="999983"):
        sd.delete_edge(3, 999983)
    # add_node registers genuinely new ids instead of raising
    sd.add_node(999983, in_neighbors={1, 2},
                out_readers={next(iter(sharded.reader_shard))})
    assert 999983 in sharded.reader_shard
    # registered but not yet compiled into any plan (delta still pending):
    # reading it must still raise, not KeyError on the owning plan's maps
    with pytest.raises(ValueError, match="999983"):
        shard_read_batch(sharded, np.array([999983]))


def test_stacked_write_drops_out_of_range_ids():
    """Negative / out-of-range base ids must be dropped on-device (like the
    single engine drops writes feeding no reader), never aliased onto base
    id 0 by the owner-map clip."""
    g, bp, ov, dec, sharded = _eagr_sharded_system(n=150, e=900, seed=12,
                                                   n_shards=3, part_seed=1)
    agg = make_aggregate("sum")
    spec = WindowSpec("tuple", 4)
    stacked = StackedShardedEngine(sharded, agg, spec)
    before = jax.device_get(stacked.state.windows.count).copy()
    stacked.write_batch(np.array([-1, 10 ** 9]),
                        np.array([5.0, 7.0], np.float32), batch_size=4)
    after = jax.device_get(stacked.state.windows.count)
    np.testing.assert_array_equal(before, after)
