"""Distributed substrate: checkpoint/restart, fault injection + replay
determinism, straggler detection, gradient compression, reader-partitioned
EAGr shards."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_freqs
from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.engine import EagrEngine, compile_plan
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.compression import (
    compress_with_feedback,
    dequantize_int8,
    init_error_state,
    quantize_int8,
)
from repro.distributed.eagr_shard import (
    partition_overlay,
    shard_read_batch,
    shard_write_batch,
)
from repro.distributed.fault import FaultTolerantRunner, StragglerDetector
from repro.graphs.generators import rmat_graph
from repro.train.optimizer import get_optimizer
from repro.train.trainer import make_train_step


# ------------------------------------------------------------- checkpointing
def _toy_state(key=0):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (8, 4)),
            "b": jnp.zeros((4,)),
            "opt": {"mu": jnp.ones((8, 4)), "count": jnp.int32(3)}}


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    state = _toy_state()
    cm.save(10, state)
    restored, manifest = cm.restore(state)
    assert manifest["step"] == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    state = _toy_state()
    for s in (1, 2, 3, 4):
        cm.save(s, state)
    assert cm.all_steps() == [3, 4]
    assert cm.latest_step() == 4


def test_checkpoint_async_and_atomicity(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    state = _toy_state()
    cm.save(7, state, blocking=False)
    cm.wait()
    assert cm.latest_step() == 7
    # a stale .tmp dir (crash mid-write) must be invisible
    import os
    os.makedirs(tmp_path / "step_00000099.tmp")
    assert cm.latest_step() == 7


def test_checkpoint_restore_with_resharding(tmp_path):
    """Restore under a different sharding (elastic restart)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cm = CheckpointManager(str(tmp_path))
    state = _toy_state()
    cm.save(1, state)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), state)
    restored, _ = cm.restore(state, shardings=sh)
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(state["w"]))


# ------------------------------------------------------- fault-tolerant loop
def test_fault_runner_replays_deterministically(tmp_path):
    """Training with injected failures must converge to the exact same state
    as an uninterrupted run (checkpoint + deterministic data replay)."""
    opt = get_optimizer("sgd")

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    step = make_train_step(loss_fn, opt, clip_norm=None)

    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, metrics = step(params, opt_state, batch, 0.05)
        return (params, opt_state), metrics

    def make_batch(i):
        k = jax.random.PRNGKey(i)
        x = jax.random.normal(k, (16, 4))
        return {"x": x, "y": x @ jnp.arange(4.0)[:, None]}

    params0 = {"w": jnp.zeros((4, 1))}
    state0 = (params0, opt.init(params0))

    cm1 = CheckpointManager(str(tmp_path / "a"))
    r1 = FaultTolerantRunner(step_fn, make_batch, cm1, ckpt_every=5)
    clean, rep1 = r1.run(state0, 30)
    assert rep1.restarts == 0

    cm2 = CheckpointManager(str(tmp_path / "b"))
    r2 = FaultTolerantRunner(step_fn, make_batch, cm2, ckpt_every=5)
    faulty, rep2 = r2.run(state0, 30, fail_at={12, 23})
    assert rep2.restarts == 2
    np.testing.assert_allclose(np.asarray(clean[0]["w"]),
                               np.asarray(faulty[0]["w"]), rtol=1e-6)


def test_straggler_detector():
    det = StragglerDetector(z=4.0)
    for i in range(20):
        det.observe(i, 0.10 + 0.001 * (i % 3))
    assert det.observe(20, 0.5)        # 5x median
    assert not det.observe(21, 0.101)


# ---------------------------------------------------------------- compression
def test_quantize_roundtrip_error_bound():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(256,)).astype(np.float32))
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) / 2 + 1e-7


def test_error_feedback_unbiased_over_steps():
    """With error feedback, the SUM of compressed grads tracks the sum of true
    grads (residual stays bounded)."""
    rng = np.random.default_rng(1)
    g_true = [jnp.asarray(rng.normal(size=(64,)).astype(np.float32) * 0.01)
              for _ in range(50)]
    err = init_error_state({"g": g_true[0]})
    acc_c = jnp.zeros(64)
    for g in g_true:
        cg, err = compress_with_feedback({"g": g}, err)
        acc_c = acc_c + cg["g"]
    acc_t = sum(g_true[1:], g_true[0])
    resid = float(jnp.abs(acc_c - acc_t).max())
    # residual equals the last carried error, bounded by one quantization step
    assert resid <= float(jnp.abs(err["g"]).max()) + 1e-6


def test_compressed_training_converges():
    opt = get_optimizer("sgd", momentum=0.0)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (128, 8))
    w_true = jnp.arange(8.0)[:, None] / 4
    y = x @ w_true
    params = {"w": jnp.zeros((8, 1))}
    err = init_error_state(params)
    opt_state = opt.init(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.mean((x @ p["w"] - y) ** 2))(params)
        cg, err = compress_with_feedback(g, err)
        params, opt_state = opt.update(cg, opt_state, params, 0.05)
    assert float(jnp.abs(params["w"] - w_true).max()) < 1e-2


# ------------------------------------------------------------ EAGr sharding
def test_reader_partitioned_shards_match_global_engine():
    g = rmat_graph(200, 1200, seed=9)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
    wf, rf = make_freqs(g.n_nodes, seed=9)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
    agg = make_aggregate("sum")
    spec = WindowSpec("tuple", 4)

    global_eng = EagrEngine(ov, dec, agg, spec)
    sharded = partition_overlay(ov, dec, n_shards=4, seed=0)
    assert sharded.replication_factor() >= 1.0
    engines = [EagrEngine(s, d, agg, spec)
               for s, d in zip(sharded.shards, sharded.shard_decisions)]

    rng = np.random.default_rng(10)
    ris = bp.reader_input_sets()
    for _ in range(4):
        ids = rng.choice(bp.writers, 64)
        vals = rng.normal(size=64).astype(np.float32)
        global_eng.write_batch(ids, vals)
        # paper §7: each write goes to every shard that consumes the writer
        for eng, (rows, v, m) in zip(engines,
                                     shard_write_batch(sharded, ids, vals)):
            sel = m.nonzero()[0]
            if sel.size:
                base_ids = [k for k in eng.plan.writer_row_of_base]  # noqa: F841
                # rows are already local rows; write directly through state
                eng.state = eng._write(eng.state, jnp.asarray(rows),
                                       jnp.asarray(v), jnp.asarray(m))

    readers = rng.choice(list(ris.keys()), 24)
    want = np.ravel(global_eng.read_batch(readers))
    for eng, (nodes, m) in zip(engines, shard_read_batch(sharded, readers)):
        if not m.any():
            continue
        ans, _ = eng._read(eng.state, jnp.asarray(nodes), jnp.asarray(m))
        ans = np.ravel(np.asarray(ans))[: int(m.sum())]
        owned = [r for r in readers if sharded.reader_shard.get(int(r)) ==
                 engines.index(eng)]
        for a, r in zip(ans, owned):
            idx = list(readers).index(r)
            np.testing.assert_allclose(a, want[idx], rtol=1e-4, atol=1e-4)


def test_shard_partition_covers_all_readers():
    g = rmat_graph(150, 900, seed=12)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=2, seed=0)
    wf, rf = make_freqs(g.n_nodes, seed=12)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
    sharded = partition_overlay(ov, dec, n_shards=3, seed=1)
    all_readers = {ov.origin[r] for r in ov.reader_nodes()}
    assert set(sharded.reader_shard.keys()) == all_readers
    for s, eng_ov in enumerate(sharded.shards):
        eng_ov.toposort()  # each shard closure is a valid DAG
