"""Engine correctness: the vectorized overlay runtime must agree with the
window-level oracle for every aggregate, overlay algorithm, window kind, and
dataflow decision mix — including after node splitting and under negative
edges / duplicate-insensitive multipaths.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import make_freqs
from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.engine import EagrEngine
from repro.core.iob import construct_iob
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph


def _drive_and_check(eng, bp, seed=0, n_batches=6, batch=48, int_vals=False,
                     n_checks=24):
    rng = np.random.default_rng(seed)
    writers = bp.writers
    readers = list(bp.reader_inputs.keys())
    ris = bp.reader_input_sets()
    for _ in range(n_batches):
        ids = rng.choice(writers, size=batch)
        vals = (rng.integers(0, 16, batch).astype(np.float32) if int_vals
                else rng.normal(size=batch).astype(np.float32))
        eng.write_batch(ids, vals)
    q = rng.choice(readers, size=n_checks)
    ans = np.asarray(eng.read_batch(q))
    for i, b in enumerate(q):
        want = eng.oracle_read(int(b), ris)
        got = ans[i]
        if eng.agg.name == "topk":
            # same count multiset: compare via counts at returned indices
            continue
        np.testing.assert_allclose(np.ravel(got), np.ravel(want),
                                   rtol=1e-4, atol=1e-4)


@pytest.fixture(scope="module")
def setup():
    g = rmat_graph(300, 2000, seed=11)
    bp = build_bipartite(g)
    wf, rf = make_freqs(g.n_nodes, seed=11)
    return bp, wf, rf


@pytest.mark.parametrize("aggname,variant", [
    ("sum", "vnm_a"), ("sum", "vnm_n"), ("count", "vnm_n"), ("avg", "vnm_a"),
    ("max", "vnm_d"), ("min", "vnm_d"), ("max", "vnm_a"), ("sum", "iob"),
])
def test_engine_matches_oracle(setup, aggname, variant):
    bp, wf, rf = setup
    if variant == "iob":
        ov, _ = construct_iob(bp, max_iterations=2)
    else:
        ov, _ = construct_vnm(bp, variant=variant, max_iterations=3, seed=0)
    ov.validate(bp.reader_input_sets())
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for(aggname))
    agg = make_aggregate(aggname)
    eng = EagrEngine(ov, dec, agg, WindowSpec(kind="tuple", size=4))
    _drive_and_check(eng, bp)


def test_engine_with_split_nodes(setup):
    bp, wf, rf = setup
    ov, _ = construct_vnm(bp, variant="vnm_n", max_iterations=3, seed=0)
    cost = D.cost_model_for("sum")
    dec, _ = D.decide_mincut(ov, wf, rf, cost)
    ov, dec, _ = D.split_nodes(ov, dec, wf, rf, cost)
    eng = EagrEngine(ov, dec, make_aggregate("sum"), WindowSpec("tuple", 4))
    _drive_and_check(eng, bp, seed=5)


def test_engine_all_push_and_all_pull(setup):
    bp, _, _ = setup
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
    for mode in ("push", "pull"):
        dec = np.array([D.PUSH if (mode == "push" or ov.kinds[v] == "W")
                        else D.PULL for v in range(ov.n_nodes)])
        eng = EagrEngine(ov, dec, make_aggregate("sum"), WindowSpec("tuple", 2))
        _drive_and_check(eng, bp, seed=6)


def test_engine_rejects_negative_edges_for_max(setup):
    bp, wf, rf = setup
    ov, _ = construct_vnm(bp, variant="vnm_n", max_iterations=3, seed=0)
    has_neg = any(s < 0 for ins in ov.in_edges for _, s in ins)
    if not has_neg:
        pytest.skip("no negative edges found on this seed")
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("max"))
    with pytest.raises(ValueError):
        EagrEngine(ov, dec, make_aggregate("max"), WindowSpec("tuple", 2))


def test_tuple_window_eviction(setup):
    """Writing w past the window size must evict the oldest values."""
    bp, wf, rf = setup
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=2, seed=0)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
    eng = EagrEngine(ov, dec, make_aggregate("sum"), WindowSpec("tuple", 2))
    w = int(bp.writers[0])
    ris = bp.reader_input_sets()
    reader = next(r for r, ins in ris.items() if w in ins)
    for v in (5.0, 7.0, 100.0):
        eng.write_batch(np.array([w]), np.array([v], np.float32))
    # window keeps the last 2 writes: 7 + 100
    got = float(np.ravel(eng.read_batch(np.array([reader])))[0])
    want = float(np.ravel(eng.oracle_read(reader, ris))[0])
    np.testing.assert_allclose(got, want, rtol=1e-5)
    single = {r: ins for r, ins in ris.items() if ins == {w}}
    if single:
        r = next(iter(single))
        assert abs(float(np.ravel(eng.read_batch(np.array([r])))[0]) - 107.0) < 1e-4


def test_topk_engine(setup):
    bp, wf, rf = setup
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("topk"))
    agg = make_aggregate("topk", k=3, domain=16)
    eng = EagrEngine(ov, dec, agg, WindowSpec("tuple", 8))
    rng = np.random.default_rng(3)
    ris = bp.reader_input_sets()
    for _ in range(6):
        eng.write_batch(rng.choice(bp.writers, 64),
                        rng.integers(0, 16, 64).astype(np.float32))
    readers = rng.choice(list(ris.keys()), 8)
    ans = np.asarray(eng.read_batch(readers))
    assert ans.shape == (8, 3)
    # count-vector oracle straight from the writer windows: the returned
    # top-1 topic must have the maximal count
    from repro.core.window import window_pao
    wp = np.asarray(window_pao(eng.state.windows, eng.spec, agg))
    for i, r in enumerate(readers):
        counts = np.zeros(16)
        for w in ris[int(r)]:
            counts += wp[eng.plan.writer_row_of_base[w]]
        assert counts[int(ans[i, 0])] == counts.max()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.sampled_from(["sum", "max"]),
       st.integers(1, 6))
def test_property_engine_oracle(seed, aggname, window):
    g = rmat_graph(80, 400, seed=seed % 7)
    bp = build_bipartite(g)
    variant = "vnm_d" if aggname == "max" else "vnm_n"
    ov, _ = construct_vnm(bp, variant=variant, max_iterations=2, seed=seed)
    ov.validate(bp.reader_input_sets())
    wf, rf = make_freqs(g.n_nodes, seed=seed)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for(aggname))
    eng = EagrEngine(ov, dec, make_aggregate(aggname),
                     WindowSpec("tuple", window))
    _drive_and_check(eng, bp, seed=seed, n_batches=3, batch=32, n_checks=12)
