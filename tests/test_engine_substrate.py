"""The unified execution substrate: kernel-backed plan parity and shape
invariants.

Three executors must agree with the window-level oracle and each other:
``pallas`` (segment_agg kernel, interpret mode on CPU), ``xla`` (the
segment_sum/segment_max fallback), and ``xla_unrolled`` (the legacy Python
unroll kept as the benchmark baseline). On top of parity, the jitted
write/read program op count must be *constant in overlay depth* for the
looped backends, and sibling shard plans must align to one program shape.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_freqs
from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.engine import (
    EagrEngine,
    _write_body_sum,
    compile_plan,
    plan_dims,
)
from repro.core.overlay import Overlay
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph
from repro.kernels.segment_agg.ops import make_leveled_plan, segment_agg_level
from repro.streams.traces import batched_playback, generate_trace

BACKENDS = ("xla", "xla_unrolled", "pallas")


@pytest.fixture(scope="module")
def setup():
    g = rmat_graph(150, 900, seed=11)
    bp = build_bipartite(g)
    wf, rf = make_freqs(g.n_nodes, seed=11)
    return bp, wf, rf


def _drive(eng, bp, *, seed=3, topics=False, vdim=1):
    rng = np.random.default_rng(seed)
    for _ in range(3):
        ids = rng.choice(bp.writers, 48)
        if topics:
            vals = rng.integers(0, 16, 48).astype(np.float32)
        elif vdim > 1:
            vals = rng.normal(size=(48, vdim)).astype(np.float32)
        else:
            vals = rng.normal(size=48).astype(np.float32)
        eng.write_batch(ids, vals)
    q = rng.choice(list(bp.reader_inputs.keys()), 16)
    return q, np.asarray(eng.read_batch(q))


@pytest.mark.parametrize("aggname,variant", [
    ("sum", "vnm_n"),    # negative overlay edges
    ("max", "vnm_d"),    # duplicate-insensitive multipaths
    ("min", "vnm_d"),
    ("avg", "vnm_a"),    # pao_dim=2
    ("topk", "vnm_a"),   # vector PAO (domain=16) exercises F lane tiling
])
def test_backend_parity_vs_oracle(setup, aggname, variant):
    bp, wf, rf = setup
    ov, _ = construct_vnm(bp, variant=variant, max_iterations=3, seed=0)
    ov.validate(bp.reader_input_sets())
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for(aggname))
    agg = (make_aggregate(aggname, k=3, domain=16) if aggname == "topk"
           else make_aggregate(aggname))
    ris = bp.reader_input_sets()
    outs = {}
    for backend in BACKENDS:
        eng = EagrEngine(ov, dec, agg, WindowSpec("tuple", 4), backend=backend)
        assert eng.plan.meta.backend == backend
        q, outs[backend] = _drive(eng, bp, topics=(aggname == "topk"))
        if aggname != "topk":  # topk finalize returns ids; compare backends only
            for i, b in enumerate(q):
                want = eng.oracle_read(int(b), ris)
                np.testing.assert_allclose(
                    np.ravel(outs[backend][i]), np.ravel(want),
                    rtol=1e-4, atol=1e-4)
    for backend in BACKENDS[1:]:
        np.testing.assert_allclose(outs[backend], outs[BACKENDS[0]],
                                   rtol=1e-4, atol=1e-4)


def test_vector_payload_parity(setup):
    """(B, F) raw write values flow through windows, kernel, and oracle."""
    bp, wf, rf = setup
    ov, _ = construct_vnm(bp, variant="vnm_n", max_iterations=3, seed=0)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
    agg = make_aggregate("sum", value_dim=3)
    ris = bp.reader_input_sets()
    outs = {}
    for backend in BACKENDS:
        eng = EagrEngine(ov, dec, agg, WindowSpec("tuple", 4, value_dim=3),
                         backend=backend)
        q, outs[backend] = _drive(eng, bp, vdim=3)
        for i, b in enumerate(q):
            want = eng.oracle_read(int(b), ris)
            np.testing.assert_allclose(np.ravel(outs[backend][i]),
                                       np.ravel(want), rtol=1e-4, atol=1e-4)
    for backend in BACKENDS[1:]:
        np.testing.assert_allclose(outs[backend], outs[BACKENDS[0]],
                                   rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ API guard rails
def test_write_batch_empty_after_filtering(setup):
    bp, wf, rf = setup
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=2, seed=0)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
    eng = EagrEngine(ov, dec, make_aggregate("sum"), WindowSpec("tuple", 2))
    non_writer = max(int(b) for b in bp.writers) + 1000
    before = np.asarray(eng.state.pao).copy()
    eng.write_batch(np.array([non_writer]), np.array([5.0], np.float32))
    eng.write_batch(np.array([], np.int64), np.array([], np.float32))
    np.testing.assert_array_equal(np.asarray(eng.state.pao), before)
    # with an explicit batch size the (masked) program still runs fine
    eng.write_batch(np.array([non_writer]), np.array([5.0], np.float32),
                    batch_size=4)
    np.testing.assert_array_equal(np.asarray(eng.state.pao), before)


def test_empty_batch_still_expires_time_windows(setup):
    """An all-dropped batch must behave like the masked program: for an
    extremal aggregate over a *time* window the PAO refresh still runs, so
    entries expire; replay with and without batch_size stays equivalent."""
    bp, wf, rf = setup
    ov, _ = construct_vnm(bp, variant="vnm_d", max_iterations=2, seed=0)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("max"))
    non_writer = max(int(b) for b in bp.writers) + 1000
    w = int(bp.writers[0])
    reader = next(r for r, ins in bp.reader_input_sets().items() if w in ins)
    answers = {}
    for label, bs in (("auto", None), ("fixed", 8)):
        eng = EagrEngine(ov, dec, make_aggregate("max"),
                         WindowSpec("time", size=2.0, capacity=4))
        eng.write_batch(np.array([w]), np.array([7.0], np.float32),
                        batch_size=bs)
        for _ in range(4):  # all-dropped batches advance time past the window
            eng.write_batch(np.array([non_writer]), np.array([1.0], np.float32),
                            batch_size=bs)
        answers[label] = float(np.ravel(eng.read_batch(np.array([reader])))[0])
    assert answers["auto"] == answers["fixed"]
    assert answers["auto"] <= -1e38  # the write at t=0 expired from [now-2]


def test_measure_plan_matches_compiled_dims(setup):
    from repro.core.engine import measure_plan
    bp, wf, rf = setup
    for variant in ("vnm_a", "vnm_n"):
        ov, _ = construct_vnm(bp, variant=variant, max_iterations=2, seed=0)
        dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
        assert measure_plan(ov, dec) == plan_dims(compile_plan(ov, dec))
    ov, dec = _chain_overlay(7)
    assert measure_plan(ov, dec) == plan_dims(compile_plan(ov, dec))


def test_read_batch_unknown_reader_raises(setup):
    bp, wf, rf = setup
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=2, seed=0)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
    eng = EagrEngine(ov, dec, make_aggregate("sum"), WindowSpec("tuple", 2))
    bogus = max(bp.reader_inputs) + 999
    with pytest.raises(ValueError, match="not.*readers"):
        eng.read_batch(np.array([bogus]))


def test_unknown_backend_rejected(setup):
    bp, wf, rf = setup
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=2, seed=0)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
    with pytest.raises(ValueError, match="backend"):
        compile_plan(ov, dec, backend="cuda")


# -------------------------------------------------- program-size invariants
def _chain_overlay(depth: int, n_writers: int = 4) -> tuple[Overlay, np.ndarray]:
    """writers -> I -> I -> ... (depth I nodes) -> reader, all PUSH."""
    ov = Overlay(kinds=[], origin=[], in_edges=[])
    ws = [ov.add_node("W", i) for i in range(n_writers)]
    prev = ov.add_node("I")
    for w in ws:
        ov.add_edge(w, prev)
    for _ in range(depth - 1):
        nxt = ov.add_node("I")
        ov.add_edge(prev, nxt)
        prev = nxt
    r = ov.add_node("R", n_writers)
    ov.add_edge(prev, r)
    dec = np.full(ov.n_nodes, D.PUSH)
    return ov, dec


def _write_eqn_count(plan, agg, spec, batch=8) -> int:
    """Trace the (unjitted) write body and count jaxpr equations."""
    fn = functools.partial(_write_body_sum.__wrapped__, plan.meta, agg, spec)
    from repro.core.engine import EngineState
    from repro.core.window import init_windows
    state = EngineState(init_windows(plan.meta.n_writers, spec),
                        agg.init_pao(plan.meta.n_nodes), jnp.float32(0.0))
    jaxpr = jax.make_jaxpr(fn)(
        plan.arrays, state, jnp.zeros(batch, jnp.int32),
        jnp.zeros(batch, jnp.float32), jnp.ones(batch, bool))
    return len(jaxpr.jaxpr.eqns)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_op_count_constant_in_depth(backend):
    agg = make_aggregate("sum")
    spec = WindowSpec("tuple", 2)
    counts = []
    for depth in (2, 7, 15):
        ov, dec = _chain_overlay(depth)
        plan = compile_plan(ov, dec, backend=backend)
        counts.append(_write_eqn_count(plan, agg, spec))
    assert counts[0] == counts[1] == counts[2], counts


def test_op_count_grows_when_unrolled():
    """The legacy baseline retains depth-proportional program size — the
    regression the substrate refactor removes."""
    agg = make_aggregate("sum")
    spec = WindowSpec("tuple", 2)
    counts = []
    for depth in (2, 15):
        ov, dec = _chain_overlay(depth)
        plan = compile_plan(ov, dec, backend="xla_unrolled")
        counts.append(_write_eqn_count(plan, agg, spec))
    assert counts[1] > counts[0], counts


def _restructured_overlay() -> tuple[Overlay, np.ndarray]:
    """Same nodes/writers as _chain_overlay(5, 4) but rewired: two partial
    aggregates merging, then a shorter chain — a §3.3-style restructure."""
    ov = Overlay(kinds=[], origin=[], in_edges=[])
    ws = [ov.add_node("W", i) for i in range(4)]
    i1, i2 = ov.add_node("I"), ov.add_node("I")
    ov.add_edge(ws[0], i1), ov.add_edge(ws[1], i1)
    ov.add_edge(ws[2], i2), ov.add_edge(ws[3], i2)
    i3 = ov.add_node("I")
    ov.add_edge(i1, i3), ov.add_edge(i2, i3)
    i4 = ov.add_node("I")
    ov.add_edge(i3, i4)
    i5 = ov.add_node("I")
    ov.add_edge(i4, i5)
    r = ov.add_node("R", 4)
    ov.add_edge(i5, r)
    return ov, np.full(ov.n_nodes, D.PUSH)


def test_restructured_overlay_same_program_shape(setup):
    """Overlay restructure (§3.3) with unchanged padded dims -> identical
    PlanMeta and array shapes -> jit cache hit instead of a retrace."""
    p1 = compile_plan(*_chain_overlay(5, n_writers=4), backend="xla")
    p2 = compile_plan(*_restructured_overlay(), backend="xla")
    assert p1.meta == p2.meta
    s1 = jax.tree.map(lambda a: a.shape, p1.arrays)
    s2 = jax.tree.map(lambda a: a.shape, p2.arrays)
    assert jax.tree.all(jax.tree.map(lambda a, b: a == b, s1, s2))


def test_restructured_overlay_hits_jit_cache():
    """The end-to-end guarantee: separately-built engines (fresh Aggregate
    instances included) over restructured overlays run ONE compiled write
    program, not two."""
    ov1, dec1 = _chain_overlay(5, n_writers=4)
    ov2, dec2 = _restructured_overlay()
    spec = WindowSpec("tuple", 2)
    assert make_aggregate("sum") == make_aggregate("sum")
    assert make_aggregate("topk", k=3) != make_aggregate("topk", k=5)
    e1 = EagrEngine(ov1, dec1, make_aggregate("sum"), spec, backend="xla")
    e2 = EagrEngine(ov2, dec2, make_aggregate("sum"), spec, backend="xla")
    ids = np.arange(4)
    vals = np.ones(4, np.float32)
    e1.write_batch(ids, vals)
    before = _write_body_sum._cache_size()
    e2.write_batch(ids, vals)
    assert _write_body_sum._cache_size() == before, "restructure retraced"


def test_shard_plans_share_one_program_shape(setup):
    from repro.distributed.eagr_shard import partition_overlay
    bp, wf, rf = setup
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=2, seed=0)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
    sharded = partition_overlay(ov, dec, n_shards=3, seed=1)
    metas = {p.meta for p in sharded.shard_plans}
    assert len(metas) == 1, "aligned shard plans must share one PlanMeta"
    shapes = [jax.tree.map(lambda a: a.shape, p.arrays)
              for p in sharded.shard_plans]
    assert all(s == shapes[0] for s in shapes[1:])


# --------------------------------------------------------- leveled kernel plan
def test_leveled_plan_matches_ref():
    from repro.kernels.segment_agg.ref import segment_agg_ref
    rng = np.random.default_rng(0)
    n_rows, F = 300, 5
    segs = [rng.integers(0, n_rows, e) for e in (40, 7, 0, 513)]
    lp = make_leveled_plan(segs, n_rows)
    assert lp.n_levels % 4 == 0 and lp.n_levels >= len(segs)
    for l, seg in enumerate(segs):
        x = rng.normal(size=(len(seg), F)).astype(np.float32)
        xp = lp.layout(l, x, fill=0.0)
        out = segment_agg_level(
            jnp.asarray(xp), jnp.asarray(lp.seg[l]),
            jnp.asarray(lp.tile_of_block[l]), jnp.asarray(lp.first_of_tile[l]),
            n_rows=n_rows, n_row_tiles=lp.n_row_tiles, op="sum")
        ref = segment_agg_ref(jnp.asarray(x), jnp.asarray(seg), n_rows, op="sum") \
            if len(seg) else jnp.zeros((n_rows, F))
        touched = np.zeros(n_rows, bool)
        touched[seg] = True
        np.testing.assert_allclose(np.asarray(out)[touched],
                                   np.asarray(ref)[touched], rtol=1e-5, atol=1e-5)


def test_padded_playback_fixed_shapes(setup):
    bp, _, _ = setup
    readers = np.array(list(bp.reader_inputs))
    trace = generate_trace(bp.writers, readers, 500, seed=2)
    shapes = set()
    n_total = 0
    for kind, ids, vals, n_live in batched_playback(trace, 64, pad=True):
        assert ids.shape == (64,) and vals.shape[0] == 64
        assert 0 < n_live <= 64
        shapes.add((ids.shape, vals.shape))
        n_total += n_live
    assert len(shapes) == 1
    assert n_total == trace.n_events
