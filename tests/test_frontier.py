"""Frontier-sparse write/read steps (PR 8): the sparse paths must be
BIT-identical to the dense sweeps — across aggregates, payload shapes,
window kinds, backends, and structural churn — because the block index
promises a *superset* of every batch's reachable frontier. Plus the trace /
transfer discipline the substrate guarantees everywhere else: power-of-two
K bucketing keeps a bounded jit cache, and steady-state sparse ingest makes
zero implicit host->device transfers. The bf16 edge-value flag
(EAGR_SEGAGG_BF16) is checked against fp32 within rounding tolerance.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dataflow as D
from repro.core import frontier as F
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.dynamic import DynamicOverlay
from repro.core.engine import EagrEngine
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph
from repro.session import EagrSession, Query


# ---------------------------------------------------------------- fixtures
def _basis(seed=3, n=150, e=900):
    g = rmat_graph(n, e, seed=seed)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
    dyn = DynamicOverlay.from_overlay(ov, bp.reader_input_sets())
    return dyn.to_overlay(prune=False)


@pytest.fixture(scope="module")
def basis():
    return _basis()


def _engine(basis, *, agg="sum", spec=None, all_push=False, backend=None,
            **agg_kwargs):
    if all_push:
        dec = np.full(basis.n_nodes, D.PUSH, np.int64)
    else:
        n = max((o for o in basis.origin if o >= 0), default=0) + 1
        wf = np.ones(n)
        dec, _ = D.decide_mincut(basis, wf, wf.copy(),
                                 D.cost_model_for("sum", window=4), window=4)
    return EagrEngine(basis, dec, make_aggregate(agg, **agg_kwargs),
                      spec or WindowSpec("tuple", 4), headroom=2.0,
                      backend=backend)


def _drive(eng, mode, monkeypatch, *, n_batches=6, arrival=16, value_dim=1,
           seed=7):
    monkeypatch.setenv("EAGR_SPARSE_WRITE", mode)
    writers = np.flatnonzero(eng.plan.routes.writer_row >= 0)
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        ids = rng.choice(writers, size=arrival).astype(np.int64)
        shape = (arrival,) if value_dim == 1 else (arrival, value_dim)
        vals = rng.integers(0, 8, shape).astype(np.float32)
        eng.write_batch(ids, vals)


def _state_tuple(eng):
    s = eng.state
    return tuple(np.asarray(jax.device_get(x)) for x in
                 (s.windows.values, s.windows.stamps, s.windows.head,
                  s.windows.count, s.pao, s.now))


def _assert_states_equal(a, b):
    for x, y in zip(_state_tuple(a), _state_tuple(b)):
        np.testing.assert_array_equal(x, y)


# ------------------------------------------------------------- bit parity
CASES = [
    ("sum_scalar_tuple", dict(), 1),
    ("sum_vector_tuple", dict(agg="sum", value_dim=3,
                              spec=WindowSpec("tuple", 4, value_dim=3)), 3),
    ("sum_scalar_time", dict(agg="sum",
                             spec=WindowSpec("time", 4, capacity=8)), 1),
    ("max_scalar_tuple", dict(agg="max", all_push=True), 1),
    ("max_scalar_time", dict(agg="max", all_push=True,
                             spec=WindowSpec("time", 4, capacity=8)), 1),
    ("min_scalar_tuple", dict(agg="min", all_push=True), 1),
    ("min_vector_time", dict(agg="min", all_push=True, value_dim=2,
                             spec=WindowSpec("time", 4, capacity=8,
                                             value_dim=2)), 2),
]


@pytest.mark.parametrize("name,kw,vdim", CASES,
                         ids=[c[0] for c in CASES])
def test_sparse_write_bit_identical_to_dense(basis, monkeypatch, name, kw,
                                             vdim):
    dense, sparse = _engine(basis, **kw), _engine(basis, **kw)
    _drive(dense, "0", monkeypatch, value_dim=vdim)
    _drive(sparse, "1", monkeypatch, value_dim=vdim)
    _assert_states_equal(dense, sparse)
    assert any(k >= 0 for k in sparse.frontier_log), \
        "forced sparse mode never took the sparse path"
    assert all(k == -1 for k in dense.frontier_log)


def test_sparse_write_bit_identical_pallas(basis, monkeypatch):
    dense = _engine(basis, backend="pallas")
    sparse = _engine(basis, backend="pallas")
    _drive(dense, "0", monkeypatch)
    _drive(sparse, "1", monkeypatch)
    _assert_states_equal(dense, sparse)


@settings(deadline=None, max_examples=12)
@given(seed=st.integers(0, 2**16), arrival=st.integers(1, 48),
       agg=st.sampled_from(["sum", "max", "min"]),
       time_window=st.booleans())
def test_sparse_write_parity_hypothesis(seed, arrival, agg, time_window):
    """Property sweep: any batch mix, aggregate and window kind — sparse
    (forced) and dense states stay bit-identical."""
    import os
    basis = _basis(seed=4, n=120, e=700)
    spec = WindowSpec("time", 4, capacity=8) if time_window \
        else WindowSpec("tuple", 4)
    kw = dict(agg=agg, all_push=agg != "sum", spec=spec)
    dense, sparse = _engine(basis, **kw), _engine(basis, **kw)
    old = os.environ.get("EAGR_SPARSE_WRITE")
    try:
        writers = np.flatnonzero(dense.plan.routes.writer_row >= 0)
        rng = np.random.default_rng(seed)
        batches = [(rng.choice(writers, arrival).astype(np.int64),
                    rng.integers(0, 8, arrival).astype(np.float32))
                   for _ in range(4)]
        os.environ["EAGR_SPARSE_WRITE"] = "0"
        for ids, vals in batches:
            dense.write_batch(ids, vals)
        os.environ["EAGR_SPARSE_WRITE"] = "1"
        for ids, vals in batches:
            sparse.write_batch(ids, vals)
    finally:
        if old is None:
            os.environ.pop("EAGR_SPARSE_WRITE", None)
        else:
            os.environ["EAGR_SPARSE_WRITE"] = old
    _assert_states_equal(dense, sparse)


def test_sparse_parity_across_churn(monkeypatch):
    """Patch the plan, then write through both paths: the incrementally
    maintained index (exact per-writer overrides from the host graph walk)
    must keep sparse bit-identical, with the EAGR_PATCH_PARITY superset
    oracle active."""
    monkeypatch.setenv("EAGR_PATCH_PARITY", "1")

    def run(mode):
        monkeypatch.setenv("EAGR_SPARSE_WRITE", mode)
        g = rmat_graph(120, 700, seed=5)
        sess = EagrSession(g)
        h = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4)))
        rng = np.random.default_rng(11)
        writers = np.array(sorted(sess.writers))
        readers = np.array(sorted(sess.readers))
        reads = []
        for step in range(10):
            ids = rng.choice(writers, size=32).astype(np.int64)
            vals = rng.integers(0, 8, 32).astype(np.float32)
            sess.update(ids, vals)
            if step % 3 == 0:
                r = int(readers[step % len(readers)])
                nbrs = sess.neighborhood(r)
                if step % 2 and nbrs:
                    sess.delete_edge(min(nbrs), r)
                else:
                    w = int(writers[(step * 7) % len(writers)])
                    if w not in nbrs and w != r:
                        sess.add_edge(w, r)
                sess.flush()
            reads.append(sess.read(h, rng.choice(readers, 8, replace=False)))
        return reads, h.group.engine

    reads_d, eng_d = run("0")
    reads_s, eng_s = run("1")
    for a, b in zip(reads_d, reads_s):
        np.testing.assert_array_equal(a, b)
    _assert_states_equal(eng_d, eng_s)
    assert eng_s.plan.patches_applied > 0
    assert eng_s.plan.frontier is not None and eng_s.plan.frontier.overrides


def test_sparse_read_bit_identical_to_dense(basis, monkeypatch):
    """Mincut decisions so pull nodes exist: the demand-chunk + pull-block
    sparse read must match the dense read exactly."""
    eng = _engine(basis)  # mincut -> pull sweep is real
    _drive(eng, "0", monkeypatch)
    readers = np.flatnonzero(eng.plan.routes.reader_node >= 0)[:24]
    monkeypatch.setenv("EAGR_SPARSE_WRITE", "0")
    dense = eng.read_batch(readers)
    monkeypatch.setenv("EAGR_SPARSE_WRITE", "1")
    sparse = eng.read_batch(readers)
    np.testing.assert_array_equal(dense, sparse)
    assert eng.plan.reader_frontier is not None


# -------------------------------------------------- trace/transfer discipline
def test_sparse_k_bucketing_bounds_trace_count(basis, monkeypatch):
    """Varying batches whose frontiers land in one (batch bucket, per-level
    K-bucket tuple) pair must reuse one compiled sparse program."""
    from repro.core.engine import _write_body_sum_sparse

    assert [F.bucket_active(k) for k in (0, 1, 7, 8, 9, 64, 65)] == \
        [0, 8, 8, 8, 16, 64, 128]
    eng = _engine(basis)
    monkeypatch.setenv("EAGR_SPARSE_WRITE", "1")
    writers = np.flatnonzero(eng.plan.routes.writer_row >= 0)
    rng = np.random.default_rng(3)

    def ktuple(ids):  # the trace-cache shape key of a batch's frontier
        rows, mask = eng.plan.routes.writer_rows(ids)
        act = eng.frontier_active(rows, mask)
        assert act is not None
        return tuple(a.shape[0] for a in act)

    warm = rng.choice(writers, 32).astype(np.int64)
    eng.write_batch(warm, np.ones(32, np.float32))  # warm (32, Ks) once
    c0 = _write_body_sum_sparse._cache_size()
    ks = {ktuple(warm)}
    for n in (17, 21, 31, 32):
        ids = rng.choice(writers, n).astype(np.int64)
        ks.add(ktuple(ids))
        eng.write_batch(ids, np.ones(n, np.float32))
    if len(ks) == 1:  # same K-tuple bucket throughout -> zero new traces
        assert _write_body_sum_sparse._cache_size() == c0
    assert _write_body_sum_sparse._cache_size() <= c0 + (len(ks) - 1)


def test_sparse_steady_state_no_implicit_transfers(basis, monkeypatch):
    """Sparse dispatch adds exactly one more explicit device_put (the active
    array) — after warmup the step must run with zero implicit h2d."""
    eng = _engine(basis)
    monkeypatch.setenv("EAGR_SPARSE_WRITE", "1")
    writers = np.flatnonzero(eng.plan.routes.writer_row >= 0)
    rng = np.random.default_rng(5)
    batches = [(rng.choice(writers, 32).astype(np.int64),
                rng.integers(0, 8, 32).astype(np.float32))
               for _ in range(8)]
    for ids, vals in batches[:4]:
        eng.write_batch(ids, vals)
    with jax.transfer_guard_host_to_device("disallow"):
        for ids, vals in batches[4:]:
            eng.write_batch(ids, vals)
    assert sum(1 for k in eng.frontier_log if k >= 0) == 8


# --------------------------------------------------------------- bf16 flag
def test_segment_agg_bf16_parity_within_tolerance(basis, monkeypatch):
    ref = _engine(basis, all_push=True)
    assert ref.plan.meta.bf16 is False
    monkeypatch.setenv("EAGR_SEGAGG_BF16", "1")
    lo = _engine(basis, all_push=True)
    assert lo.plan.meta.bf16 is True
    monkeypatch.delenv("EAGR_SEGAGG_BF16")
    writers = np.flatnonzero(ref.plan.routes.writer_row >= 0)
    rng = np.random.default_rng(7)
    for _ in range(5):
        ids = rng.choice(writers, 16).astype(np.int64)
        vals = (rng.random(16) * 8).astype(np.float32)
        ref.write_batch(ids, vals)
        lo.write_batch(ids, vals)
    pr = np.asarray(jax.device_get(ref.state.pao))
    pl = np.asarray(jax.device_get(lo.state.pao))
    assert not np.array_equal(pr, pl) or np.abs(pr).max() == 0.0
    np.testing.assert_allclose(pl, pr, rtol=0.05, atol=0.5)


def test_bf16_sparse_matches_bf16_dense(basis, monkeypatch):
    """bf16 rounding must commute with the sparse gather: sparse bf16 ==
    dense bf16 bit-for-bit."""
    monkeypatch.setenv("EAGR_SEGAGG_BF16", "1")
    dense, sparse = _engine(basis), _engine(basis)
    monkeypatch.delenv("EAGR_SEGAGG_BF16")
    _drive(dense, "0", monkeypatch)
    _drive(sparse, "1", monkeypatch)
    _assert_states_equal(dense, sparse)


# ------------------------------------------------------------- index units
def test_frontier_blocks_cover_closures(basis):
    """Both index flavors must be supersets of their flavor-matched closure
    walk (the invariant `verify` enforces after churn, checked here at
    build), and the source-exact flavor must never exceed the span flavor."""
    from repro.core.plan_patch import PlanHost

    eng = _engine(basis, all_push=True)
    plan = eng.plan
    if plan.host is None:
        plan.host = PlanHost.from_plan(plan, eng.overlay)
    fi = F.FrontierIndex.build(plan)              # destination spans
    fi.verify(plan, plan.host)  # raises on any under-coverage
    fx = F.FrontierIndex.build(plan, exact=True)  # source-exact (sum)
    fx.verify(plan, plan.host)
    for node, row in fx.row_of_node.items():
        spans = fi.blocks_of(fi.row_of_node[node])
        for l, blks in fx.blocks_of(row).items():
            assert blks <= spans.get(l, set())


def test_frontier_density_fallback_and_unknown_rows(basis):
    eng = _engine(basis, all_push=True)
    fi = F.FrontierIndex.build(eng.plan)
    rows = np.arange(fi.n_base_rows)
    assert fi.expand(rows, density=0.0) is None       # too dense -> fallback
    act = fi.expand(rows[:2], density=None)           # forced sparse
    assert act is not None and len(act) == eng.plan.meta.n_levels
    nb = fi.n_blocks
    # within each level: int32, ascending actives, pads (== nb) at the end;
    # an empty level packs to shape (0,)
    for lvl in act:
        assert lvl.dtype == np.int32
        assert lvl.size == 0 or lvl.max() <= nb
        real = lvl[lvl < nb]
        assert (np.diff(real) > 0).all()
        assert (lvl[len(real):] == nb).all()
    assert fi.expand(np.array([fi.n_base_rows + 99]), density=None) is None


def test_stacked_sparse_bit_identical_to_dense(monkeypatch):
    """The stacked shard_map write must dispatch the same sparse bodies per
    shard (per-level widths shared across the stack) and stay bit-identical
    to dense."""
    from repro.distributed.eagr_shard import partition_overlay
    from repro.distributed.stacked import StackedShardedEngine

    def run(mode):
        monkeypatch.setenv("EAGR_SPARSE_WRITE", mode)
        g = rmat_graph(200, 1200, seed=9)
        bp = build_bipartite(g)
        ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
        rng0 = np.random.default_rng(9)
        wf, rf = rng0.random(g.n_nodes) + 0.1, rng0.random(g.n_nodes) + 0.1
        dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
        sharded = partition_overlay(ov, dec, n_shards=4, seed=0)
        eng = StackedShardedEngine(sharded, make_aggregate("sum"),
                                   WindowSpec("tuple", 4))
        rng = np.random.default_rng(10)
        for _ in range(5):
            ids = rng.choice(bp.writers, 24)
            eng.write_batch(ids, rng.normal(size=24).astype(np.float32),
                            batch_size=24)
        return [np.asarray(jax.device_get(x)) for x in
                jax.tree_util.tree_leaves(
                    (eng.state.windows.values, eng.state.windows.stamps,
                     eng.state.pao, eng.state.now))]

    for x, y in zip(run("0"), run("1")):
        np.testing.assert_array_equal(x, y)


def test_frontier_auto_mode_gates(basis, monkeypatch):
    """auto: a batch touching most writers skips expansion entirely (dense);
    EAGR_SPARSE_WRITE=0 forces dense even for tiny batches."""
    eng = _engine(basis, all_push=True)
    writers = np.flatnonzero(eng.plan.routes.writer_row >= 0)
    monkeypatch.setenv("EAGR_SPARSE_WRITE", "auto")
    monkeypatch.setenv("EAGR_SPARSE_ROWFRAC", "0.05")
    big = np.resize(writers, max(64, len(writers)))
    rows, mask = eng.plan.routes.writer_rows(big)
    assert eng.frontier_active(rows, mask) is None
    monkeypatch.setenv("EAGR_SPARSE_WRITE", "0")
    rows, mask = eng.plan.routes.writer_rows(writers[:4])
    assert eng.frontier_active(rows, mask) is None
