"""Streaming ingest pipeline (PR 7): the async double-buffered ring must be
BIT-identical to the synchronous write path at the same device-batch
boundaries — scalar and vector payloads, sum and extremal/time windows,
partial-slot drains — and it must inherit the substrate's transfer
discipline (zero implicit host->device transfers in steady state). Plus the
vectorized-routing invariants the pipeline rides on: the dense
``BaseRoutes`` LUT tracks the bookkeeping dicts under churn, and default
batches land on power-of-two compiled shapes only.
"""
import jax
import numpy as np
import pytest

from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.dynamic import DynamicOverlay
from repro.core.engine import EagrEngine, bucket_batch
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph
from repro.session import EagrSession, Query
from repro.streams.ingest import IngestPipeline


# ---------------------------------------------------------------- fixtures
def _basis(seed=3, n=150, e=900):
    g = rmat_graph(n, e, seed=seed)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
    dyn = DynamicOverlay.from_overlay(ov, bp.reader_input_sets())
    return g, bp, dyn.to_overlay(prune=False)


def _engine(basis, *, agg="sum", spec=None, all_push=False, **agg_kwargs):
    if all_push:
        dec = np.full(basis.n_nodes, D.PUSH, np.int64)
    else:
        n = max((o for o in basis.origin if o >= 0), default=0) + 1
        wf = np.ones(n)
        dec, _ = D.decide_mincut(basis, wf, wf.copy(),
                                 D.cost_model_for("sum", window=4), window=4)
    return EagrEngine(basis, dec, make_aggregate(agg, **agg_kwargs),
                      spec or WindowSpec("tuple", 4), headroom=2.0)


def _batches(eng, *, n_batches, arrival, value_dim=1, seed=7,
             with_unknown=True):
    """Zipf-free random write batches over known writer bases; every third
    batch carries one unknown (droppable) base id to exercise masking."""
    writers = np.flatnonzero(eng.plan.routes.writer_row >= 0)
    unknown = np.flatnonzero(eng.plan.routes.writer_row < 0)
    rng = np.random.default_rng(seed)
    out = []
    for k in range(n_batches):
        ids = rng.choice(writers, size=arrival).astype(np.int64)
        if with_unknown and len(unknown) and k % 3 == 0:
            ids[0] = unknown[0]
        shape = (arrival,) if value_dim == 1 else (arrival, value_dim)
        vals = rng.integers(0, 8, shape).astype(np.float32)
        out.append((ids, vals))
    return out


def _state_tuple(eng):
    s = eng.state
    return tuple(np.asarray(jax.device_get(x)) for x in
                 (s.windows.values, s.windows.stamps, s.windows.head,
                  s.windows.count, s.pao, s.now))


def _assert_states_equal(a, b):
    for x, y in zip(_state_tuple(a), _state_tuple(b)):
        np.testing.assert_array_equal(x, y)


def _sync_twin_drive(eng, batches, device_batch):
    """The synchronous reference: identical events at identical device-batch
    boundaries (full slots + one partial tail, exactly what the ring
    dispatches)."""
    ids = np.concatenate([i for i, _ in batches])
    vals = np.concatenate([v for _, v in batches])
    for off in range(0, len(ids), device_batch):
        eng.write_batch(ids[off: off + device_batch],
                        vals[off: off + device_batch],
                        batch_size=device_batch)


# ----------------------------------------------------------- bit parity
@pytest.mark.parametrize("case", ["sum_scalar", "sum_vector", "max_time"])
def test_pipeline_bit_identical_to_sync(case):
    g, bp, basis = _basis()
    if case == "sum_scalar":
        make = lambda: _engine(basis)  # noqa: E731
        vdim = 1
    elif case == "sum_vector":
        make = lambda: _engine(  # noqa: E731
            basis, agg="sum", value_dim=3,
            spec=WindowSpec("tuple", 4, value_dim=3))
        vdim = 3
    else:
        make = lambda: _engine(  # noqa: E731
            basis, agg="max", all_push=True,
            spec=WindowSpec("time", 4, capacity=8))
        vdim = 1

    piped, sync = make(), make()
    B = 64
    # 11 arrival batches of 16 = 176 events: 2 full slots + a partial tail
    batches = _batches(piped, n_batches=11, arrival=16, value_dim=vdim)

    pipe = IngestPipeline([piped], depth=2, device_batch=B)
    for ids, vals in batches:
        pipe.submit(ids, vals)
    pipe.flush()
    _sync_twin_drive(sync, batches, B)

    _assert_states_equal(piped, sync)
    assert pipe.stats.events_in == 176
    assert pipe.stats.partial_batches == 1

    readers = np.flatnonzero(piped.plan.routes.reader_node >= 0)[:32]
    np.testing.assert_array_equal(
        piped.read_batch(readers, batch_size=32),
        sync.read_batch(readers, batch_size=32))


def test_drain_dispatches_partial_without_blocking():
    g, bp, basis = _basis()
    piped, sync = _engine(basis), _engine(basis)
    batches = _batches(piped, n_batches=3, arrival=16, with_unknown=False)
    pipe = IngestPipeline([piped], depth=2, device_batch=64)
    for ids, vals in batches:
        pipe.submit(ids, vals)
    assert pipe.pending == 48
    pipe.drain()  # partial slot dispatched, ring not barriered
    assert pipe.pending == 0
    assert pipe.stats.partial_batches == 1
    _sync_twin_drive(sync, batches, 64)
    # the read's data dependency on the engine state sequences it after the
    # drained write — no flush needed for visibility
    readers = np.flatnonzero(piped.plan.routes.reader_node >= 0)[:16]
    np.testing.assert_array_equal(
        piped.read_batch(readers, batch_size=16),
        sync.read_batch(readers, batch_size=16))


# ----------------------------------------------- session + churn ordering
def test_session_pipeline_matches_sync_session_under_churn():
    """Interleaved updates and add_edge/delete_edge through two sessions —
    one pipelined (ingest_depth=2, device batch == update batch, so batch
    boundaries match), one synchronous — must stay bit-comparable on reads,
    and both must match the windows oracle. The churn flush is the pipeline
    barrier: patches land only after every in-flight write step."""
    g = rmat_graph(120, 700, seed=5)
    spec = WindowSpec("tuple", 4)
    piped = EagrSession(g, ingest_depth=2, ingest_batch=32)
    sync = EagrSession(g)
    hp = piped.register(Query(agg="sum", window=spec))
    hs = sync.register(Query(agg="sum", window=spec))

    rng = np.random.default_rng(11)
    writers = np.array(sorted(piped.writers))
    readers = np.array(sorted(set(piped.readers) & set(sync.readers)))

    def mutate(step):
        r = int(readers[step % len(readers)])
        nbrs = piped.neighborhood(r)
        if step % 2 and nbrs:
            w = min(nbrs)
            piped.delete_edge(w, r)
            sync.delete_edge(w, r)
        else:
            w = int(writers[(step * 7) % len(writers)])
            if w not in nbrs and w != r:
                piped.add_edge(w, r)
                sync.add_edge(w, r)

    for step in range(8):
        ids = rng.choice(writers, size=32).astype(np.int64)
        vals = rng.integers(0, 8, 32).astype(np.float32)
        piped.update(ids, vals)
        sync.update(ids, vals)
        if step % 3 == 0:
            mutate(step)  # journaled; auto-flushes on the next update/read
        sample = rng.choice(readers, size=8, replace=False)
        np.testing.assert_array_equal(piped.read(hp, sample),
                                      sync.read(hs, sample))

    piped.flush()
    sync.flush()
    # oracle: answers straight from the writer windows, independent of the
    # overlay and of the write path
    eng = hp.group.engine
    for r in map(int, readers[:5]):
        want = eng.oracle_read(r, {r: piped.neighborhood(r)})
        got = piped.read(hp, [r])[0]
        np.testing.assert_allclose(got, want, rtol=0, atol=0)


# ------------------------------------------------------ transfer discipline
def test_pipeline_steady_state_no_implicit_transfers():
    """After warmup (compile + ring wrap) the pipeline must run entirely on
    explicit ``device_put`` — the transfer guard turns any implicit
    host->device transfer (stray np array or Python scalar reaching a jitted
    body) into an error."""
    g, bp, basis = _basis()
    eng = _engine(basis)
    pipe = IngestPipeline([eng], depth=2, device_batch=32)
    batches = _batches(eng, n_batches=12, arrival=32)
    for ids, vals in batches[:4]:  # compile both branches, wrap the ring
        pipe.submit(ids, vals)
    with jax.transfer_guard_host_to_device("disallow"):
        for ids, vals in batches[4:]:
            pipe.submit(ids, vals)
        pipe.flush()
    assert pipe.stats.batches == 12


# --------------------------------------------------- routing + batch shapes
def test_default_batch_size_buckets_compiled_shapes():
    """``batch_size=None`` pads to the power-of-two ``bucket_batch`` bucket:
    after warming one bucket, every smaller batch in that bucket reuses the
    compiled program (no new jit cache entries)."""
    from repro.core.engine import _read_body, _write_body_sum

    assert [bucket_batch(n) for n in (1, 16, 17, 31, 32, 33)] == \
        [16, 16, 32, 32, 32, 64]

    g, bp, basis = _basis()
    eng = _engine(basis)
    writers = np.flatnonzero(eng.plan.routes.writer_row >= 0)
    readers = np.flatnonzero(eng.plan.routes.reader_node >= 0)
    ids = np.resize(writers, 32).astype(np.int64)
    eng.write_batch(ids, np.ones(32, np.float32))  # warm the 32 bucket
    eng.read_batch(np.resize(readers, 32))
    c0 = (_write_body_sum._cache_size(), _read_body._cache_size())
    for n in (17, 21, 31, 32):
        eng.write_batch(ids[:n], np.ones(n, np.float32))
        eng.read_batch(np.resize(readers, n))
    assert (_write_body_sum._cache_size(), _read_body._cache_size()) == c0, \
        "default-sized batches inside one bucket must not compile new shapes"


def _assert_routes_match_dicts(plan):
    r = plan.routes
    for table, m in ((r.writer_row, plan.writer_row_of_base),
                     (r.reader_node, plan.reader_node_of_base)):
        want = np.full(len(table), -1, np.int32)
        for b, v in m.items():
            want[b] = v
        np.testing.assert_array_equal(table, want)


def test_routes_table_tracks_dicts_under_churn():
    """The dense routing LUT (hot path) and the bookkeeping dicts
    (authoritative) must agree after every patch: adds, deletes, node
    retirement."""
    g = rmat_graph(120, 700, seed=5)
    sess = EagrSession(g)
    h = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4)))
    _assert_routes_match_dicts(h.group.engine.plan)

    readers = sorted(sess.readers)
    sess.add_edge(readers[0], readers[1])
    sess.delete_edge(min(sess.neighborhood(readers[2])), readers[2])
    sess.add_node(5000, in_neighbors=[readers[0]], out_readers=[readers[1]])
    sess.flush()
    _assert_routes_match_dicts(h.group.engine.plan)

    sess.delete_node(5000)
    sess.flush()
    _assert_routes_match_dicts(h.group.engine.plan)
    sess.update([readers[1]], [2.0])  # the patched plan still routes
    assert np.isfinite(sess.read(h, [readers[1]])[0])
