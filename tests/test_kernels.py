"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import flash_attention, flash_decode
from repro.kernels.flash_attention.ref import attention_ref, decode_ref
from repro.kernels.segment_agg.ops import make_plan, segment_agg
from repro.kernels.segment_agg.ref import segment_agg_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("E,F,n_rows", [
    (100, 8, 17), (1000, 64, 300), (37, 5, 10), (4096, 128, 128),
    (513, 200, 77), (1, 1, 1), (2000, 96, 1000),
])
@pytest.mark.parametrize("op", ["sum", "max"])
def test_segment_agg_sweep(E, F, n_rows, op):
    seg = RNG.integers(0, n_rows, E)
    x = RNG.normal(size=(E, F)).astype(np.float32)
    plan = make_plan(seg, n_rows)
    out = np.asarray(segment_agg(jnp.asarray(x), plan, op=op))
    ref = np.asarray(segment_agg_ref(jnp.asarray(x), jnp.asarray(seg), n_rows, op=op))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_segment_agg_dtypes(dtype):
    seg = RNG.integers(0, 50, 500)
    x = RNG.normal(size=(500, 32)).astype(np.float32)
    plan = make_plan(seg, 50)
    out = np.asarray(segment_agg(jnp.asarray(x, dtype=dtype), plan, op="sum"))
    ref = np.asarray(segment_agg_ref(jnp.asarray(x, dtype=dtype).astype(jnp.float32),
                                     jnp.asarray(seg), 50, op="sum"))
    tol = 1e-5 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)


def test_segment_agg_empty_rows():
    seg = np.array([5, 5, 5])
    x = np.ones((3, 4), np.float32)
    plan = make_plan(seg, 10)
    out = np.asarray(segment_agg(jnp.asarray(x), plan, op="max"))
    assert np.allclose(out[5], 1.0) and np.allclose(out[0], 0.0)


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 2, 1, 128, 32), (2, 4, 2, 256, 64), (1, 8, 8, 512, 64),
    (2, 6, 2, 200, 48),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, Hq, Hkv, S, D, causal):
    q = jnp.asarray(RNG.normal(size=(B, Hq, S, D)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)).astype(np.float32))
    out = flash_attention(q, k, v, causal=causal, q_blk=128, k_blk=128,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,Hq,Hkv,Skv,D", [
    (2, 4, 2, 512, 64), (1, 8, 1, 1024, 32), (3, 6, 3, 300, 64),
])
def test_flash_decode_sweep(B, Hq, Hkv, Skv, D):
    q = jnp.asarray(RNG.normal(size=(B, Hq, D)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Skv, D)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Skv, D)).astype(np.float32))
    lens = jnp.asarray(RNG.integers(1, Skv, B).astype(np.int32))
    out = flash_decode(q, k, v, lens, k_blk=128, interpret=True)
    ref = decode_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("V,D,n_ids,n_bags", [
    (100, 16, 64, 8), (1000, 32, 256, 16), (500, 64, 100, 100),
    (64, 8, 16, 1),
])
def test_embedding_bag_sweep(V, D, n_ids, n_bags):
    table = jnp.asarray(RNG.normal(size=(V, D)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(0, V, n_ids).astype(np.int32))
    cuts = np.sort(RNG.choice(np.arange(1, n_ids), size=n_bags - 1,
                              replace=False)) if n_bags > 1 else np.array([], np.int64)
    offs = jnp.asarray(np.concatenate([[0], cuts]).astype(np.int32))
    out = embedding_bag(table, ids, offs, n_bags=n_bags, interpret=True)
    bags = np.zeros(n_ids, np.int32)
    offs_np = np.asarray(offs)
    for i in range(n_bags):
        end = offs_np[i + 1] if i + 1 < n_bags else n_ids
        bags[offs_np[i]:end] = i
    ref = embedding_bag_ref(table, ids, jnp.asarray(bags), n_bags)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_embedding_bag_weighted():
    table = jnp.asarray(RNG.normal(size=(50, 8)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(0, 50, 32).astype(np.int32))
    offs = jnp.asarray(np.arange(0, 32, 4).astype(np.int32))
    w = jnp.asarray(RNG.normal(size=32).astype(np.float32))
    out = embedding_bag(table, ids, offs, n_bags=8, weights=w, interpret=True)
    bags = np.repeat(np.arange(8, dtype=np.int32), 4)
    ref = embedding_bag_ref(table, ids, jnp.asarray(bags), 8, weights=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_segment_agg_matches_engine_path():
    """The Pallas segment kernel computes the same contraction the EAGr engine
    and GNNs use via jax.ops.segment_sum."""
    E, F, n = 777, 36, 99
    seg = RNG.integers(0, n, E)
    x = RNG.normal(size=(E, F)).astype(np.float32)
    plan = make_plan(seg, n)
    out = np.asarray(segment_agg(jnp.asarray(x), plan, op="sum"))
    ref = np.asarray(jax.ops.segment_sum(jnp.asarray(x), jnp.asarray(seg),
                                         num_segments=n))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
