"""GNN models: segment message passing vs dense reference, equivariance,
masking invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import init_from_specs
from repro.models.gnn import gat, gatedgcn, graphcast, nequip
from repro.models.gnn.common import GraphBatch, agg_sum, segment_softmax
from repro.models.gnn.equivariant import (
    intertwiner,
    random_rotation,
    real_sph_harm,
    wigner_d,
)

RNG = np.random.default_rng(0)


def _batch(n=40, e=160, f=12, classes=5, seed=0):
    rng = np.random.default_rng(seed)
    return GraphBatch(
        x=jnp.asarray(rng.normal(size=(n, f)).astype(np.float32)),
        edge_src=jnp.asarray(rng.integers(0, n, e).astype(np.int32)),
        edge_dst=jnp.asarray(rng.integers(0, n, e).astype(np.int32)),
        edge_mask=jnp.ones(e, bool), node_mask=jnp.ones(n, bool),
        labels=jnp.asarray(rng.integers(0, classes, n).astype(np.int32)),
        label_mask=jnp.ones(n, bool))


def test_segment_softmax_vs_dense():
    n, e = 10, 60
    dst = jnp.asarray(RNG.integers(0, n, e).astype(np.int32))
    scores = jnp.asarray(RNG.normal(size=(e, 3)).astype(np.float32))
    alpha = np.asarray(segment_softmax(scores, dst, n))
    for v in range(n):
        idx = np.asarray(dst) == v
        if idx.any():
            want = np.exp(np.asarray(scores)[idx])
            want /= want.sum(axis=0, keepdims=True)
            np.testing.assert_allclose(alpha[idx], want, rtol=1e-5, atol=1e-6)
    # masked rows sum to 1 per destination
    sums = np.asarray(jax.ops.segment_sum(jnp.asarray(alpha), dst, num_segments=n))
    present = np.asarray(jax.ops.segment_sum(jnp.ones(e), dst, num_segments=n)) > 0
    np.testing.assert_allclose(sums[present], 1.0, rtol=1e-5)


def test_gat_vs_dense_reference():
    """GAT layer == dense-adjacency attention on a small graph."""
    cfg = gat.GATConfig(n_layers=1, d_hidden=6, n_heads=2, d_in=5, n_classes=6)
    params = init_from_specs(gat.param_specs(cfg), jax.random.PRNGKey(0))
    b = _batch(n=12, e=40, f=5, seed=1)
    out = np.asarray(gat.forward(params, b, cfg))
    # dense reference
    p = params["layer0"]
    x = np.asarray(b.x)
    h = np.einsum("nf,fho->nho", x, np.asarray(p["w"]))
    es = np.einsum("nho,ho->nh", h, np.asarray(p["a_src"]))
    ed = np.einsum("nho,ho->nh", h, np.asarray(p["a_dst"]))
    n = x.shape[0]
    ref = np.zeros_like(out)
    src, dst = np.asarray(b.edge_src), np.asarray(b.edge_dst)
    for v in range(n):
        idx = np.nonzero(dst == v)[0]
        acc = np.zeros((2, 6))
        if idx.size:
            s = es[src[idx]] + ed[v]
            s = np.where(s > 0, s, 0.2 * s)
            a = np.exp(s - s.max(axis=0))
            a /= a.sum(axis=0)
            acc = (h[src[idx]] * a[:, :, None]).sum(axis=0)
        ref[v] = (acc + np.asarray(p["bias"])).mean(axis=0)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_gat_ignores_masked_edges():
    cfg = gat.GATConfig(n_layers=2, d_hidden=4, n_heads=2, d_in=5, n_classes=3)
    params = init_from_specs(gat.param_specs(cfg), jax.random.PRNGKey(1))
    b = _batch(n=20, e=80, f=5, classes=3, seed=2)
    import dataclasses as dc
    # masking an edge == deleting it
    mask = np.ones(80, bool); mask[13] = False
    b_masked = dc.replace(b, edge_mask=jnp.asarray(mask))
    keep = np.nonzero(mask)[0]
    b_deleted = dc.replace(
        b, edge_src=b.edge_src[keep], edge_dst=b.edge_dst[keep],
        edge_mask=jnp.ones(len(keep), bool))
    o1 = np.asarray(gat.forward(params, b_masked, cfg))
    o2 = np.asarray(gat.forward(params, b_deleted, cfg))
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-5)


def test_gatedgcn_runs_and_trains():
    cfg = gatedgcn.GatedGCNConfig(n_layers=3, d_hidden=16, d_in=12, n_classes=5)
    params = init_from_specs(gatedgcn.param_specs(cfg), jax.random.PRNGKey(2))
    b = _batch(seed=3)
    loss, _ = gatedgcn.loss_fn(params, b, cfg)
    g = jax.grad(lambda p: gatedgcn.loss_fn(p, b, cfg)[0])(params)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


def test_graphcast_mesh_sizes():
    assert graphcast.mesh_sizes(0) == (12, 60)
    assert graphcast.mesh_sizes(6) == (40962, 2 * 163830)


def test_graphcast_forward_shapes():
    cfg = graphcast.GraphCastConfig(n_layers=2, d_hidden=16, mesh_refinement=1,
                                    n_vars=4, compute_dtype=jnp.float32)
    params = init_from_specs(graphcast.param_specs(cfg), jax.random.PRNGKey(3))
    G, M, Em = 30, cfg.n_mesh, cfg.n_mesh_edges
    rng = np.random.default_rng(4)
    b = graphcast.GraphCastBatch(
        grid_x=jnp.asarray(rng.normal(size=(G, 4)).astype(np.float32)),
        g2m_src=jnp.asarray(rng.integers(0, G, 90).astype(np.int32)),
        g2m_dst=jnp.asarray(rng.integers(0, M, 90).astype(np.int32)),
        mesh_src=jnp.asarray(rng.integers(0, M, Em).astype(np.int32)),
        mesh_dst=jnp.asarray(rng.integers(0, M, Em).astype(np.int32)),
        m2g_src=jnp.asarray(rng.integers(0, M, 90).astype(np.int32)),
        m2g_dst=jnp.asarray(rng.integers(0, G, 90).astype(np.int32)),
        targets=jnp.zeros((G, 4)))
    out = graphcast.forward(params, b, cfg)
    assert out.shape == (G, 4) and bool(jnp.isfinite(out).all())


# ------------------------------------------------------------- equivariance
@pytest.mark.parametrize("l", [1, 2, 3])
def test_wigner_d_is_representation(l):
    R1, R2 = random_rotation(), random_rotation()
    D12 = wigner_d(l, R1 @ R2)
    err = np.abs(D12 - wigner_d(l, R1) @ wigner_d(l, R2)).max()
    assert err < 1e-10
    D = wigner_d(l, R1)
    assert np.abs(D @ D.T - np.eye(2 * l + 1)).max() < 1e-10


@pytest.mark.parametrize("lll", [(1, 1, 0), (1, 1, 1), (1, 1, 2), (2, 1, 1),
                                 (2, 2, 2), (2, 2, 0), (0, 1, 1)])
def test_intertwiner_equivariance(lll):
    l1, l2, l3 = lll
    T = intertwiner(l1, l2, l3)
    R = random_rotation()
    D1, D2, D3 = wigner_d(l1, R), wigner_d(l2, R), wigner_d(l3, R)
    u = RNG.normal(size=2 * l1 + 1)
    v = RNG.normal(size=2 * l2 + 1)
    lhs = np.einsum("kij,i,j->k", T, D1 @ u, D2 @ v)
    rhs = D3 @ np.einsum("kij,i,j->k", T, u, v)
    np.testing.assert_allclose(lhs, rhs, atol=1e-10)


def test_intertwiner_special_cases():
    T110 = intertwiner(1, 1, 0) * np.sqrt(3.0)
    np.testing.assert_allclose(T110[0], np.eye(3), atol=1e-10)   # dot product
    T111 = intertwiner(1, 1, 1)
    np.testing.assert_allclose(T111, -T111.transpose(0, 2, 1), atol=1e-10)  # cross
    assert intertwiner(0, 1, 2) is None  # outside CG range


def test_sph_harm_rotation_covariance():
    R = random_rotation()
    pts = RNG.normal(size=(20, 3))
    pts /= np.linalg.norm(pts, axis=-1, keepdims=True)
    for l in (1, 2):
        D = wigner_d(l, R)
        np.testing.assert_allclose(
            real_sph_harm(l, pts @ R.T), real_sph_harm(l, pts) @ D.T, atol=1e-10)


def test_nequip_energy_invariance_force_equivariance():
    cfg = nequip.NequIPConfig(n_layers=2, d_hidden=8, n_species=4)
    params = init_from_specs(nequip.param_specs(cfg), jax.random.PRNGKey(5))
    rng = np.random.default_rng(6)
    N, E, G = 24, 80, 2
    pos = jnp.asarray(rng.normal(size=(N, 3)).astype(np.float32) * 2)
    spec = jnp.asarray(rng.integers(0, 4, N).astype(np.int32))
    es = jnp.asarray(rng.integers(0, N, E).astype(np.int32))
    ed = jnp.asarray(rng.integers(0, N, E).astype(np.int32))
    em = jnp.asarray(np.asarray(es) != np.asarray(ed))
    nm = jnp.ones(N, bool)
    gid = jnp.asarray((np.arange(N) >= 12).astype(np.int32))
    R = jnp.asarray(random_rotation().astype(np.float32))
    t = jnp.asarray(rng.normal(size=(3,)).astype(np.float32))
    e1, f1, _ = nequip.energy_and_forces(params, pos, spec, es, ed, em, nm, gid, G, cfg)
    e2, f2, _ = nequip.energy_and_forces(params, pos @ R.T + t, spec, es, ed,
                                         em, nm, gid, G, cfg)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(f1) @ np.asarray(R).T, np.asarray(f2),
                               rtol=2e-3, atol=2e-3)


def test_nequip_permutation_invariance():
    """Energy must be invariant under atom relabeling."""
    cfg = nequip.NequIPConfig(n_layers=2, d_hidden=8, n_species=4)
    params = init_from_specs(nequip.param_specs(cfg), jax.random.PRNGKey(7))
    rng = np.random.default_rng(8)
    N, E = 16, 48
    pos = rng.normal(size=(N, 3)).astype(np.float32) * 2
    spec = rng.integers(0, 4, N).astype(np.int32)
    es = rng.integers(0, N, E).astype(np.int32)
    ed = rng.integers(0, N, E).astype(np.int32)
    gid = np.zeros(N, np.int32)
    e1 = nequip.forward_energy(params, jnp.asarray(pos), jnp.asarray(spec),
                               jnp.asarray(es), jnp.asarray(ed),
                               jnp.asarray(es != ed), jnp.ones(N, bool),
                               jnp.asarray(gid), 1, cfg)
    perm = rng.permutation(N)
    inv = np.argsort(perm)
    e2 = nequip.forward_energy(params, jnp.asarray(pos[perm]),
                               jnp.asarray(spec[perm]),
                               jnp.asarray(inv[es]), jnp.asarray(inv[ed]),
                               jnp.asarray(es != ed), jnp.ones(N, bool),
                               jnp.asarray(gid), 1, cfg)
    np.testing.assert_allclose(float(e1[0]), float(e2[0]), rtol=1e-4)
