"""LM transformer family: loss sanity, MoE dispatch equivalence, decode vs
prefill consistency, fused CE vs naive CE, vocab padding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.common import fused_ce_loss, init_from_specs

CFG = T.TransformerConfig(
    name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=97, head_dim=16, compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def params():
    return init_from_specs(T.param_specs(CFG), jax.random.PRNGKey(0))


def test_vocab_padding():
    assert CFG.padded_vocab == 128
    specs = T.param_specs(CFG)
    assert specs["embed"].shape[0] == 128
    assert specs["lm_head"].shape[1] == 128


def test_loss_finite_and_grads(params):
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    (loss, m), grads = jax.value_and_grad(
        lambda p: T.loss_fn(p, batch, CFG), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


def test_fused_ce_matches_naive(params):
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (2, 32), 0, CFG.vocab)
    labels = jax.random.randint(key, (2, 32), 0, CFG.vocab)
    x, _ = T.trunk(params, tokens, CFG)
    ce, zl = fused_ce_loss(x, params["lm_head"], labels,
                           n_valid_vocab=CFG.vocab, z_loss=1e-4, chunk=8)
    # naive: full logits with padded-vocab masking
    logits = x.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
    logits = jnp.where(jnp.arange(CFG.padded_vocab) < CFG.vocab, logits, -jnp.inf)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    np.testing.assert_allclose(float(ce), float(jnp.mean(lse - ll)), rtol=1e-5)
    np.testing.assert_allclose(float(zl), float(1e-4 * jnp.mean(lse ** 2)), rtol=1e-5)


def test_fused_ce_gradient_matches_naive(params):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (2, 16, CFG.d_model))
    labels = jax.random.randint(key, (2, 16), 0, CFG.vocab)

    def fused(w):
        ce, zl = fused_ce_loss(x, w, labels, n_valid_vocab=CFG.vocab,
                               z_loss=1e-4, chunk=4)
        return ce + zl

    def naive(w):
        logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
        logits = jnp.where(jnp.arange(w.shape[1]) < CFG.vocab, logits, -jnp.inf)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - ll) + 1e-4 * jnp.mean(lse ** 2)

    g1 = jax.grad(fused)(params["lm_head"])
    g2 = jax.grad(naive)(params["lm_head"])
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=2e-4, atol=2e-5)


def test_moe_einsum_vs_sort_dispatch():
    cfg_e = T.TransformerConfig(
        name="m", n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
        vocab=31, head_dim=16, n_experts=4, top_k=2, moe_impl="einsum",
        capacity_factor=8.0, compute_dtype=jnp.float32, remat="none")
    cfg_s = dataclasses.replace(cfg_e, moe_impl="sort")
    p = init_from_specs(T.param_specs(cfg_e), jax.random.PRNGKey(2))
    tok = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, 31)
    le, _ = T.forward(p, tok, cfg_e)
    ls, _ = T.forward(p, tok, cfg_s)
    np.testing.assert_allclose(np.asarray(le), np.asarray(ls),
                               rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_tokens_consistently():
    """With tiny capacity both impls drop; outputs stay finite."""
    cfg = T.TransformerConfig(
        name="m", n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
        vocab=31, head_dim=16, n_experts=4, top_k=2, moe_impl="sort",
        capacity_factor=0.5, compute_dtype=jnp.float32, remat="none")
    p = init_from_specs(T.param_specs(cfg), jax.random.PRNGKey(4))
    tok = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0, 31)
    logits, aux = T.forward(p, tok, cfg)
    assert bool(jnp.isfinite(logits).all()) and np.isfinite(float(aux))


def test_decode_matches_prefill(params):
    key = jax.random.PRNGKey(6)
    tokens = jax.random.randint(key, (2, 12), 0, CFG.vocab)
    logits, cache = T.prefill(params, tokens, CFG)
    next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
    S_max = 16
    k = jnp.pad(cache[0], ((0, 0), (0, 0), (0, 0), (0, S_max - 12), (0, 0)))
    v = jnp.pad(cache[1], ((0, 0), (0, 0), (0, 0), (0, S_max - 12), (0, 0)))
    lengths = jnp.full((2,), 12, jnp.int32)
    lg2, _, lens2 = T.decode_step(params, (k, v), next_tok, lengths, CFG)
    ref, _ = T.prefill(params, jnp.concatenate([tokens, next_tok[:, None]], 1), CFG)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert (np.asarray(lens2) == 13).all()


def test_decode_respects_ragged_lengths(params):
    """Rows with different cache lengths decode independently."""
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (2, 12), 0, CFG.vocab)
    _, cache = T.prefill(params, tokens, CFG)
    S_max = 16
    k = jnp.pad(cache[0], ((0, 0), (0, 0), (0, 0), (0, S_max - 12), (0, 0)))
    v = jnp.pad(cache[1], ((0, 0), (0, 0), (0, 0), (0, S_max - 12), (0, 0)))
    tok = jnp.array([1, 2], jnp.int32)
    lengths = jnp.array([5, 12], jnp.int32)
    lg, _, _ = T.decode_step(params, (k, v), tok, lengths, CFG)
    # row 0 must equal decoding with a cache truncated to 5
    _, cache5 = T.prefill(params, tokens[:, :5], CFG)
    k5 = jnp.pad(cache5[0], ((0, 0), (0, 0), (0, 0), (0, S_max - 5), (0, 0)))
    v5 = jnp.pad(cache5[1], ((0, 0), (0, 0), (0, 0), (0, S_max - 5), (0, 0)))
    lg5, _, _ = T.decode_step(params, (k5, v5), tok, jnp.array([5, 5], jnp.int32), CFG)
    np.testing.assert_allclose(np.asarray(lg[0]), np.asarray(lg5[0]),
                               rtol=2e-4, atol=2e-4)


def test_remat_matches_no_remat(params):
    cfg_n = dataclasses.replace(CFG, remat="none")
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    l1, _ = T.loss_fn(params, batch, CFG)
    l2, _ = T.loss_fn(params, batch, cfg_n)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_scan_unroll_equivalence(params):
    cfg_u = dataclasses.replace(CFG, scan_unroll=CFG.n_layers)
    tok = jnp.ones((2, 8), jnp.int32)
    l1, _ = T.forward(params, tok, CFG)
    l2, _ = T.forward(params, tok, cfg_u)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5,
                               atol=1e-5)
