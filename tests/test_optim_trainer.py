"""Optimizers + trainer: convergence, accumulation equivalence, clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import adafactor, adamw, get_optimizer, sgd
from repro.train.trainer import make_train_step


def _quadratic_problem(key=0, n=16):
    k = jax.random.PRNGKey(key)
    x = jax.random.normal(k, (64, n))
    w_true = jax.random.normal(jax.random.PRNGKey(key + 1), (n, 1))
    y = x @ w_true
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}
    return {"x": x, "y": y}, {"w": jnp.zeros((n, 1))}, w_true, loss_fn


@pytest.mark.parametrize("name,lr,steps,tol", [
    ("adamw", 0.05, 400, 1e-2),
    # adafactor's relative-step clipping makes it sign-SGD-like: needs a
    # decaying lr to settle (as in real usage)
    ("adafactor", 0.5, 800, 5e-2),
    ("sgd", 0.05, 400, 1e-2),
])
def test_optimizers_converge(name, lr, steps, tol):
    batch, params, w_true, loss_fn = _quadratic_problem()
    opt = get_optimizer(name) if name != "adamw" else adamw(weight_decay=0.0)
    step = jax.jit(make_train_step(loss_fn, opt, clip_norm=None))
    opt_state = opt.init(params)
    for t in range(steps):
        lr_t = lr / np.sqrt(t + 1.0) if name == "adafactor" else lr
        params, opt_state, m = step(params, opt_state, batch, lr_t)
    assert float(m["loss"]) < tol, float(m["loss"])


def test_adafactor_layer_chunked_matches_unchunked():
    k = jax.random.PRNGKey(0)
    p = {"w": jax.random.normal(k, (4, 8, 6))}    # layer-stacked 3D param
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (4, 8, 6)) * 0.1}
    # clip disabled: with clipping active the semantics intentionally differ
    # (per-layer clip vs per-stacked-tensor clip — see optimizer docstring)
    o1 = adafactor(layer_chunked=True, clip=1e9)
    o2 = adafactor(layer_chunked=False, clip=1e9)
    s1, s2 = o1.init(p), o2.init(p)
    p1, s1 = o1.update(g, s1, p, 0.1)
    p2, s2 = o2.update(g, s2, p, 0.1)
    np.testing.assert_allclose(np.asarray(s1.vr["w"]), np.asarray(s2.vr["w"]),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-5, atol=1e-6)


def test_accumulation_matches_full_batch():
    """accum_steps=4 must equal the single-batch gradient step (fp32 accum)."""
    batch, params, _, loss_fn = _quadratic_problem()
    opt = sgd(momentum=0.0)
    s1 = make_train_step(loss_fn, opt, accum_steps=1, clip_norm=None)
    s4 = make_train_step(loss_fn, opt, accum_steps=4, clip_norm=None,
                         accum_dtype=jnp.float32)
    p1, _, m1 = s1(params, opt.init(params), batch, 0.1)
    p4, _, m4 = s4(params, opt.init(params), batch, 0.1)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)


def test_unrolled_accum_matches_scanned():
    batch, params, _, loss_fn = _quadratic_problem(key=3)
    opt = sgd(momentum=0.0)
    a = make_train_step(loss_fn, opt, accum_steps=4, clip_norm=None)
    b = make_train_step(loss_fn, opt, accum_steps=4, clip_norm=None,
                        unroll_accum=True)
    pa, _, _ = a(params, opt.init(params), batch, 0.1)
    pb, _, _ = b(params, opt.init(params), batch, 0.1)
    np.testing.assert_allclose(np.asarray(pa["w"]), np.asarray(pb["w"]),
                               rtol=1e-6)


def test_grad_clipping_bounds_update():
    batch, params, _, loss_fn = _quadratic_problem(key=5)
    big_batch = {"x": batch["x"] * 100, "y": batch["y"] * 100}
    opt = sgd(momentum=0.0)
    step = make_train_step(loss_fn, opt, clip_norm=1.0)
    p, _, m = step(params, opt.init(params), big_batch, 1.0)
    assert float(m["grad_norm"]) > 1.0
    delta = float(jnp.abs(p["w"] - params["w"]).max())
    assert delta <= 1.0 + 1e-5   # lr * clipped-norm bound


def test_adamw_weight_decay_shrinks():
    p = {"w": jnp.ones((4, 4)) * 10}
    g = {"w": jnp.zeros((4, 4))}
    opt = adamw(weight_decay=0.1)
    s = opt.init(p)
    p2, _ = opt.update(g, s, p, 0.1)
    assert float(p2["w"][0, 0]) < 10.0
