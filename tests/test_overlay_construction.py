"""Overlay construction: correctness of every algorithm + property tests.

The central invariant (paper §2.2.1): for each reader, the net signed path
count from every writer in N(reader) is exactly 1 (>=1 for duplicate-
insensitive overlays), and 0 from writers outside N(reader).
Overlay.validate() checks exactly this via the contributions() DP.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bipartite import Bipartite, build_bipartite
from repro.core.iob import construct_iob
from repro.core.vnm import construct_vnm
from repro.graphs.csr import CSRGraph
from repro.graphs.generators import rmat_graph, small_example_graph

ALGOS = ["vnm", "vnm_a", "vnm_n", "vnm_d"]


@pytest.mark.parametrize("variant", ALGOS)
def test_vnm_variants_correct_on_example(example_bipartite, variant):
    ov, stats = construct_vnm(example_bipartite, variant=variant,
                              max_iterations=4, seed=0)
    ov.validate(example_bipartite.reader_input_sets())
    assert stats.iterations >= 1


@pytest.mark.parametrize("variant", ALGOS)
def test_vnm_variants_correct_on_rmat(rmat_bipartite, variant):
    ov, _ = construct_vnm(rmat_bipartite, variant=variant,
                          max_iterations=4, seed=0)
    ov.validate(rmat_bipartite.reader_input_sets())


def test_iob_correct_and_compact(rmat_bipartite):
    ov, _ = construct_iob(rmat_bipartite, max_iterations=2)
    ov.validate(rmat_bipartite.reader_input_sets())
    ov_a, _ = construct_vnm(rmat_bipartite, variant="vnm_a", max_iterations=4)
    # paper §5.2: IOB finds more compact overlays than VNM_A
    assert ov.n_edges <= ov_a.n_edges


def test_sharing_index_positive_on_compressible_graph():
    # a graph with many shared neighborhoods (two dense blocks)
    src, dst = [], []
    for b in range(2):
        writers = range(b * 30, b * 30 + 10)
        readers = range(b * 30 + 10, b * 30 + 30)
        for w in writers:
            for r in readers:
                src.append(w), dst.append(r)
    g = CSRGraph.from_edges(np.array(src), np.array(dst), 60)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=4)
    ov.validate(bp.reader_input_sets())
    si = ov.sharing_index(bp.n_edges)
    assert si > 0.5, si  # 10x20 bicliques compress ~10x


def test_negative_edges_only_for_subtractable():
    # vnm_n can produce negative edges; validate() checks net contribution,
    # and the engine refuses negative-edge overlays for MAX (see engine test)
    bp = build_bipartite(rmat_graph(200, 1600, seed=3))
    ov, _ = construct_vnm(bp, variant="vnm_n", max_iterations=4, seed=0)
    ov.validate(bp.reader_input_sets())


def test_dup_insensitive_allows_multipaths():
    bp = build_bipartite(rmat_graph(200, 1600, seed=4))
    ov, _ = construct_vnm(bp, variant="vnm_d", max_iterations=4, seed=0)
    assert ov.dup_insensitive
    ov.validate(bp.reader_input_sets())  # net count >= 1 allowed


def test_depth_and_levels_consistent(rmat_bipartite):
    ov, _ = construct_iob(rmat_bipartite, max_iterations=2)
    levels = ov.levels()
    for dst in range(ov.n_nodes):
        for src, _ in ov.in_edges[dst]:
            assert levels[src] < levels[dst]
    depths = ov.depth_per_reader()
    assert max(depths.values()) == max(levels[r] for r in ov.reader_nodes())


# ---------------------------------------------------------------- properties
@st.composite
def random_bipartite(draw):
    n = draw(st.integers(8, 40))
    density = draw(st.floats(0.05, 0.5))
    seed = draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    m = rng.random((n, n)) < density
    np.fill_diagonal(m, False)
    src, dst = np.nonzero(m)
    if src.size == 0:
        src, dst = np.array([0]), np.array([1])
    g = CSRGraph.from_edges(src, dst, n)
    return build_bipartite(g)


@settings(max_examples=30, deadline=None)
@given(random_bipartite(), st.sampled_from(ALGOS))
def test_property_construction_exactness(bp, variant):
    """Any constructed overlay computes exactly the bipartite spec, and never
    has (materially) more edges than the trivial (direct) overlay.

    vnm_n exception (found by hypothesis): a quasi-biclique's per-reader
    acceptance check is local, so interacting rewrites across mining rounds
    can net a few extra edges on tiny adversarial graphs — bounded by the
    number of negative edges introduced. Correctness (validate) always holds.
    """
    ov, _ = construct_vnm(bp, variant=variant, max_iterations=3, seed=1)
    ov.validate(bp.reader_input_sets())
    if variant == "vnm_n":
        n_neg = sum(1 for ins in ov.in_edges for _, sign in ins if sign < 0)
        assert ov.n_edges <= bp.n_edges + n_neg
    else:
        assert ov.n_edges <= bp.n_edges


@settings(max_examples=20, deadline=None)
@given(random_bipartite())
def test_property_iob_exactness(bp):
    ov, _ = construct_iob(bp, max_iterations=2)
    ov.validate(bp.reader_input_sets())
    assert ov.n_edges <= bp.n_edges


@settings(max_examples=20, deadline=None)
@given(random_bipartite())
def test_property_overlay_is_dag(bp):
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=2)
    ov.toposort()  # raises on a cycle
