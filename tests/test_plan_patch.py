"""Incremental plan maintenance (§3.3): OverlayDelta journaling, in-place
PlanArrays patching (slot claims, level relayouts, recompile fallback),
engine state migration, the touched-row eviction restriction, and shard
delta routing. The load-bearing invariant: a churn sequence within slot
headroom triggers ZERO new jit traces while every read stays exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import make_freqs
from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.dynamic import DynamicOverlay
from repro.core.engine import (
    EagrEngine,
    _read_body,
    _refresh_pao,
    _write_body_extremal,
    _write_body_sum,
    compile_plan,
    grow_pad,
    measure_plan,
    plan_dims,
)
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph
from repro.kernels.segment_agg.ops import (
    E_BLK,
    make_leveled_plan,
    patch_level,
    relayout_level,
    segment_agg_level,
    tile_slot_ranges,
)


def _system(n=120, e=700, seed=3, variant="vnm_a", agg="sum",
            spec=None, backend="xla", headroom=2.0, rng_seed=1):
    g = rmat_graph(n, e, seed=seed)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant=variant, max_iterations=2, seed=0)
    ris = bp.reader_input_sets()
    dyn = DynamicOverlay.from_overlay(ov, ris)
    ov0 = dyn.to_overlay(prune=False)
    wf, rf = make_freqs(n, seed=rng_seed)
    dec, _ = D.decide_mincut(ov0, wf, rf, D.cost_model_for(agg))
    aggregate = make_aggregate(agg)
    eng = EagrEngine(ov0, dec, aggregate, spec or WindowSpec("tuple", 4),
                     backend=backend, headroom=headroom)
    return eng, dyn, bp


def _cache_sizes():
    return (_write_body_sum._cache_size(), _write_body_extremal._cache_size(),
            _read_body._cache_size(), _refresh_pao._cache_size())


def _check_reads(eng, dyn, rng, k=6, batch=8):
    pool = [r for r in dyn.reader_inputs
            if dyn.reader_inputs[r] and r in eng.plan.reader_node_of_base]
    q = rng.choice(pool, k)
    out = eng.read_batch(q, batch_size=batch)
    for i, b in enumerate(q):
        want = eng.oracle_read(int(b), dyn.reader_inputs)
        np.testing.assert_allclose(np.ravel(out[i]), np.ravel(want),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"reader {b}")


def _churn_step(dyn, rng, readers, n_base=120):
    op = int(rng.integers(0, 4))
    if op == 0:
        dyn.add_edge(int(rng.integers(0, n_base)), int(rng.choice(readers)))
    elif op == 1:
        r = int(rng.choice(readers))
        if dyn.reader_inputs.get(r):
            dyn.delete_edge(int(next(iter(dyn.reader_inputs[r]))), r)
    elif op == 2:
        nid = int(rng.integers(1000, 2000))
        dyn.add_node(nid,
                     in_neighbors={int(x) for x in rng.integers(0, n_base, 3)},
                     out_readers={int(rng.choice(readers))})
    else:
        victims = [k for k in list(dyn.reader_inputs) if k >= 1000]
        if victims:
            dyn.delete_node(int(rng.choice(victims)))


# ------------------------------------------------------- kernel slot helpers
def test_leveled_plan_emits_tile_slots():
    rng = np.random.default_rng(0)
    segs = [rng.integers(0, 300, e) for e in (40, 513)]
    lp = make_leveled_plan(segs, 300)
    assert lp.tile_slots.shape == (lp.n_levels, lp.n_row_tiles, 2)
    for l in range(lp.n_levels):
        np.testing.assert_array_equal(
            lp.tile_slots[l], tile_slot_ranges(lp.tile_of_block[l],
                                               lp.n_row_tiles))
        for t in range(lp.n_row_tiles):
            a, b = lp.tile_slots[l, t]
            # every real edge slot of tile t lies inside its declared range
            in_tile = np.flatnonzero((lp.seg[l] >= 0)
                                     & (lp.seg[l] // 128 == t))
            if in_tile.size:
                assert a <= in_tile.min() and in_tile.max() < b


def test_patch_level_claim_and_retire_slots():
    """Retiring an edge via the padding pattern and claiming a free slot in
    the owning tile is value-equivalent to rebuilding the plan."""
    rng = np.random.default_rng(1)
    n_rows = 256
    seg0 = rng.integers(0, n_rows, 40)
    lp = make_leveled_plan([seg0], n_rows)
    src0 = rng.integers(0, n_rows, 40)
    seg = jnp.asarray(lp.seg)
    src = jnp.asarray(lp.layout(0, src0.astype(np.int32), fill=0))[None]
    sign = jnp.asarray(lp.layout(0, np.ones(40, np.float32), fill=0.0))[None]
    val = jnp.asarray(rng.normal(size=(n_rows, 3)).astype(np.float32))

    def run(seg, src, sign):
        x = val[src[0]] * sign[0][:, None]
        return np.asarray(segment_agg_level(
            x, seg[0], jnp.asarray(lp.tile_of_block[0]),
            jnp.asarray(lp.first_of_tile[0]), n_rows=n_rows,
            n_row_tiles=lp.n_row_tiles, op="sum"))

    # retire edge 0 (slot = perm[0]) and claim a free slot for a new edge
    retire = int(lp.perms[0][0])
    tile = int(seg0[5]) // 128
    a, b = lp.tile_slots[0, tile]
    occupied = set(int(s) for s in np.flatnonzero(np.asarray(lp.seg[0]) >= 0))
    free = [s for s in range(int(a), int(b)) if s not in occupied]
    assert free, "E_BLK rounding must leave claimable slots"
    new_dst, new_src = int(seg0[5]), 7
    seg2, src2, sign2 = patch_level(
        seg, src, sign, 0, [retire, free[0]], [-1, new_dst], [0, new_src],
        [0.0, 1.0])
    got = run(seg2, src2, sign2)
    want_seg = np.concatenate([seg0[1:], [new_dst]])
    want_src = np.concatenate([src0[1:], [new_src]])
    ref = np.zeros((n_rows, 3), np.float32)
    np.add.at(ref, want_seg, np.asarray(val)[want_src])
    touched = np.zeros(n_rows, bool)
    touched[want_seg] = True
    np.testing.assert_allclose(got[touched], ref[touched], rtol=1e-5,
                               atol=1e-5)


def test_relayout_level_respects_block_budget():
    rng = np.random.default_rng(2)
    dst = rng.integers(0, 256, 30)
    src = rng.integers(0, 256, 30)
    sign = np.ones(30)
    lp = make_leveled_plan([dst], 256)
    nb = lp.seg.shape[1] // E_BLK
    out = relayout_level(dst, src, sign, 256, nb, lp.e_pad)
    assert out is not None
    seg_row = out[0]
    assert (np.sort(seg_row[seg_row >= 0]) == np.sort(dst)).all()
    # a level that cannot fit the budget is refused, not silently truncated
    big = rng.integers(0, 256, nb * E_BLK + 1)
    assert relayout_level(big, big, np.ones_like(big), 256, nb,
                          nb * E_BLK) is None


# -------------------------------------------------------- delta journaling
def test_drain_delta_snapshots_and_resets():
    _, dyn, bp = _system()
    assert dyn.drain_delta().empty
    r = int(list(bp.reader_inputs)[0])
    w = int(bp.writers[0])
    if w in dyn.reader_inputs.get(r, set()):
        dyn.delete_edge(w, r)
    else:
        dyn.add_edge(w, r)
    delta = dyn.drain_delta()
    assert not delta.empty and delta.nodes
    rid = dyn.reader_node[r]
    assert rid in delta.nodes
    assert delta.nodes[rid].kind == "R"
    assert r in delta.touched_readers
    assert dyn.drain_delta().empty  # journal resets
    # node retirement is journaled with base-id bookkeeping
    dyn.add_node(1500, in_neighbors={w}, out_readers={r})
    d2 = dyn.drain_delta()
    assert 1500 in d2.new_writers and 1500 in d2.new_readers
    dyn.delete_node(1500)
    d3 = dyn.drain_delta()
    assert 1500 in d3.retired_writers and 1500 in d3.retired_readers
    merged = d2.merge(d3)
    assert 1500 in merged.retired_writers and 1500 not in merged.new_writers


# --------------------------------------------------- in-capacity churn: core
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_churn_patches_without_retrace(backend):
    """The acceptance invariant: a churn sequence within slot headroom
    triggers zero recompiles AND zero new jit traces, while every read stays
    exact against the window oracle."""
    eng, dyn, bp = _system(backend=backend, headroom=2.0)
    rng = np.random.default_rng(7)
    readers = list(bp.reader_inputs)

    def write():
        ids = rng.choice(bp.writers, 16)
        vals = rng.normal(size=16).astype(np.float32)
        eng.write_batch(ids, vals, batch_size=16)

    write()
    _check_reads(eng, dyn, rng)
    # prime the patch machinery once (compiles the refresh program)
    dyn.add_edge(int(bp.writers[0]), int(readers[0]))
    assert not eng.apply_delta(dyn.drain_delta()).recompiled
    write()
    _check_reads(eng, dyn, rng)
    before = _cache_sizes()
    recompiles = 0
    for step in range(25):
        _churn_step(dyn, rng, readers)
        res = eng.apply_delta(dyn.drain_delta())
        recompiles += bool(res.recompiled)
        write()
        _check_reads(eng, dyn, rng)
    assert recompiles == 0, "churn exceeded headroom"
    assert _cache_sizes() == before, "in-capacity patches must not retrace"
    assert eng.plan.patches_applied >= 20


def test_patched_engine_matches_fresh_compile():
    """After churn, the patched plan answers exactly like an engine freshly
    compiled from the same (unpruned) overlay fed the same write stream."""
    eng, dyn, bp = _system(headroom=2.0)
    rng = np.random.default_rng(11)
    readers = list(bp.reader_inputs)
    writes = []
    for step in range(15):
        _churn_step(dyn, rng, readers)
        eng.apply_delta(dyn.drain_delta())
        ids = rng.choice(bp.writers, 16)
        vals = rng.normal(size=16).astype(np.float32)
        writes.append((ids, vals))
        eng.write_batch(ids, vals, batch_size=16)
    ov2 = dyn.to_overlay(prune=False)
    fresh = EagrEngine(ov2, eng.plan.decision, make_aggregate("sum"),
                       WindowSpec("tuple", 4), backend="xla")
    for ids, vals in writes:
        fresh.write_batch(ids, vals, batch_size=16)
    q = np.array([r for r in dyn.reader_inputs
                  if dyn.reader_inputs[r]
                  and r in eng.plan.reader_node_of_base][:12])
    np.testing.assert_allclose(eng.read_batch(q), fresh.read_batch(q),
                               rtol=1e-4, atol=1e-4)


def test_patch_reuses_freed_slots():
    """Delete + re-add of the same edge stays on the slot fast path: the
    freed slot is reclaimed, no level rebuild, no recompile."""
    eng, dyn, bp = _system(headroom=2.0)
    r = next(r for r, s in dyn.reader_inputs.items() if s)
    w = int(next(iter(dyn.reader_inputs[r])))
    dyn.delete_edge(w, r)
    res1 = eng.apply_delta(dyn.drain_delta())
    assert not res1.recompiled and res1.stats["edges_removed"] >= 1
    dyn.add_edge(w, r)
    res2 = eng.apply_delta(dyn.drain_delta())
    assert not res2.recompiled
    assert res2.stats["edges_added"] >= 1
    assert res2.stats["levels_rebuilt"] == 0
    rng = np.random.default_rng(0)
    eng.write_batch(rng.choice(bp.writers, 16),
                    rng.normal(size=16).astype(np.float32), batch_size=16)
    _check_reads(eng, dyn, rng)


def test_recompile_fallback_with_growth_then_patch():
    """Exceeding capacity falls back to compile_plan with growth headroom;
    the next small delta patches in place again."""
    eng, dyn, bp = _system(headroom=None)  # natural padding only
    rng = np.random.default_rng(13)
    eng.write_batch(rng.choice(bp.writers, 16),
                    rng.normal(size=16).astype(np.float32), batch_size=16)
    for r in list(bp.reader_inputs)[:6]:
        dyn.add_reader_inputs(int(r), {int(x) for x in rng.integers(0, 120, 40)})
    res = eng.apply_delta(dyn.drain_delta())
    assert res.recompiled and res.reason
    _check_reads(eng, dyn, rng)
    dims_after = plan_dims(eng.plan)
    dyn.add_edge(int(bp.writers[1]), int(list(bp.reader_inputs)[0]))
    res2 = eng.apply_delta(dyn.drain_delta())
    assert not res2.recompiled, "growth headroom must absorb the next delta"
    assert plan_dims(eng.plan) == dims_after
    _check_reads(eng, dyn, rng)


def test_node_lifecycle_add_write_read_delete():
    eng, dyn, bp = _system(headroom=2.0)
    rng = np.random.default_rng(17)
    r0 = int(list(bp.reader_inputs)[0])
    dyn.add_node(777, in_neighbors={int(bp.writers[0]), int(bp.writers[1])},
                 out_readers={r0})
    res = eng.apply_delta(dyn.drain_delta())
    assert not res.recompiled
    eng.write_batch(np.array([777]), np.array([4.5], np.float32), batch_size=4)
    eng.write_batch(np.array([int(bp.writers[0])]), np.array([2.0], np.float32),
                    batch_size=4)
    out = eng.read_batch(np.array([777, r0]), batch_size=4)
    for i, b in enumerate([777, r0]):
        want = eng.oracle_read(int(b), dyn.reader_inputs)
        np.testing.assert_allclose(np.ravel(out[i]), np.ravel(want),
                                   rtol=1e-4, atol=1e-4)
    dyn.delete_node(777)
    eng.apply_delta(dyn.drain_delta())
    with pytest.raises(ValueError, match="not.*readers"):
        eng.read_batch(np.array([777]))
    # writes to the retired base are dropped, reads elsewhere stay exact
    before = np.asarray(eng.state.pao).copy()
    eng.write_batch(np.array([777]), np.array([9.0], np.float32))
    np.testing.assert_array_equal(np.asarray(eng.state.pao), before)
    _check_reads(eng, dyn, rng)


def test_same_epoch_add_delete_keeps_writer_rows_stable():
    """A writer added and deleted within one drain epoch must still claim a
    window row on the patch path — otherwise a later recompile (which
    enumerates every W-kind node of the unpruned overlay) would shift all
    subsequently-added writers' rows and corrupt positionally-migrated
    window state. Regression: writes to the post-phantom writer survived a
    capacity-fallback recompile."""
    eng, dyn, bp = _system(headroom=2.0)
    rng = np.random.default_rng(29)
    r0 = int(list(bp.reader_inputs)[0])
    # phantom: writer node created and retired inside one epoch
    dyn.add_node(1000, in_neighbors=set(), out_readers={r0})
    dyn.delete_node(1000)
    delta = dyn.drain_delta()
    assert delta.new_writer_nodes, "phantom W node must be row-allocated"
    eng.apply_delta(delta)
    # a later writer gets the next row...
    dyn.add_node(1001, in_neighbors=set(), out_readers={r0})
    eng.apply_delta(dyn.drain_delta())
    eng.write_batch(np.array([1001]), np.array([123.0], np.float32),
                    batch_size=4)
    before = float(np.ravel(eng.read_batch(np.array([r0])))[0])
    # ...and keeps it across a forced capacity-fallback recompile (keep
    # joining users — whose windows stay empty, so r0's sum is unchanged —
    # until some padded dim overflows)
    res = None
    for k in range(12):
        for j in range(60):
            dyn.add_node(2000 + 100 * k + j,
                         in_neighbors={int(x) for x in rng.integers(0, 120, 3)},
                         out_readers={r0})
        res = eng.apply_delta(dyn.drain_delta())
        if res.recompiled:
            break
    assert res is not None and res.recompiled
    after = float(np.ravel(eng.read_batch(np.array([r0])))[0])
    want = eng.oracle_read(r0, dyn.reader_inputs)
    np.testing.assert_allclose(after, np.ravel(want), rtol=1e-4, atol=1e-4)
    assert abs(after - before) < 1e-3, "writer 1001's window row moved"


def test_empty_delta_is_free():
    eng, dyn, _ = _system(headroom=2.0)
    state_before = eng.state
    res = eng.apply_delta(dyn.drain_delta())
    assert res.reason == "empty delta" and not res.recompiled
    assert eng.state is state_before  # no refresh program, no state swap


def test_grow_pad_monotone_and_aligned():
    pad = measure_plan(*_chain())
    g = grow_pad(pad, 2.0)
    for f in ("n_nodes", "n_writers", "n_levels", "push_blocks",
              "pull_blocks", "demand_edges"):
        assert getattr(g, f) >= getattr(pad, f)
    assert g.n_levels % 4 == 0
    assert g.push_blocks & (g.push_blocks - 1) == 0  # power of two


def _chain(depth=5, n_writers=4):
    from repro.core.overlay import Overlay
    ov = Overlay(kinds=[], origin=[], in_edges=[])
    ws = [ov.add_node("W", i) for i in range(n_writers)]
    prev = ov.add_node("I")
    for w in ws:
        ov.add_edge(w, prev)
    for _ in range(depth - 1):
        nxt = ov.add_node("I")
        ov.add_edge(prev, nxt)
        prev = nxt
    r = ov.add_node("R", n_writers)
    ov.add_edge(prev, r)
    return ov, np.full(ov.n_nodes, D.PUSH)


# ------------------------------------------- eviction: touched-row recompute
def test_extremal_time_window_skips_noop_batches():
    """All-dropped batches below the expiry boundary skip the device program
    entirely; the batch that crosses it runs — and answers match a replay
    that executes the masked program every time."""
    eng, dyn, bp = _system(variant="vnm_d", agg="max",
                           spec=WindowSpec("time", size=2.0, capacity=4),
                           headroom=2.0)
    non_writer = max(int(b) for b in bp.writers) + 1000
    w = int(bp.writers[0])
    calls = []
    inner = eng._write
    eng._write = lambda *a, **k: (calls.append(1), inner(*a, **k))[1]
    eng.write_batch(np.array([w]), np.array([7.0], np.float32))
    assert len(calls) == 1
    eng.write_batch(np.array([non_writer]), np.array([1.0], np.float32))
    eng.write_batch(np.array([non_writer]), np.array([1.0], np.float32))
    assert len(calls) == 1, "pre-boundary empty batches must not dispatch"
    eng.write_batch(np.array([non_writer]), np.array([1.0], np.float32))
    assert len(calls) == 2, "the expiry-crossing batch must run"
    eng.write_batch(np.array([non_writer]), np.array([1.0], np.float32))
    assert len(calls) == 2, "after expiry the heap is drained"
    reader = next(r for r, ins in dyn.reader_inputs.items() if w in ins)
    got = float(np.ravel(eng.read_batch(np.array([reader])))[0])
    assert got <= -1e38  # the t=0 write expired from [now-2, now]


def test_extremal_touched_restriction_matches_always_run():
    """Auto mode (deadline skipping + touched-row-restricted recompute) must
    equal fixed-batch mode (program runs every batch) after every batch."""
    eng_a, dyn, bp = _system(variant="vnm_d", agg="max",
                             spec=WindowSpec("time", size=3.0, capacity=6),
                             headroom=2.0, seed=5)
    eng_f, _, _ = _system(variant="vnm_d", agg="max",
                          spec=WindowSpec("time", size=3.0, capacity=6),
                          headroom=2.0, seed=5)
    rng = np.random.default_rng(23)
    readers = np.array(list(bp.reader_inputs))
    non_writer = max(int(b) for b in bp.writers) + 1000
    for k in range(12):
        if k % 3 == 2:
            ids = np.array([non_writer])  # all-dropped batch
            vals = np.array([1.0], np.float32)
        else:
            ids = rng.choice(bp.writers, 8)
            vals = rng.normal(size=8).astype(np.float32)
        eng_a.write_batch(ids, vals)
        eng_f.write_batch(ids, vals, batch_size=8)
        q = rng.choice(readers, 6)
        np.testing.assert_allclose(eng_a.read_batch(q, batch_size=8),
                                   eng_f.read_batch(q, batch_size=8),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"batch {k}")


# -------------------------------------------------------------- sharded path
def test_patch_program_buckets_and_single_trace():
    """The device patch program is cache-keyed by shape-bucketed edit counts:
    bursts touching 1..N slots must NOT compile one executable per distinct
    count (the measured-45ms-each failure mode) — slot-only churn stays on
    ONE cached ``apply_patch_step`` trace — and the patched plan stays
    exact."""
    from repro.core.plan_patch import _bucket, apply_patch_step

    assert _bucket(1, 64) == 64
    assert _bucket(64, 64) == 64
    assert _bucket(65, 64) == 256

    eng, dyn, bp = _system(headroom=2.0)
    rng = np.random.default_rng(0)
    readers = [r for r in dyn.reader_inputs if dyn.reader_inputs[r]]
    c0 = apply_patch_step._cache_size()
    for k in range(6):  # bursts of 1..6 edge adds -> varying slot counts
        for _ in range(k + 1):
            dyn.add_edge(int(rng.integers(0, 120)), int(rng.choice(readers)))
        res = eng.apply_delta(dyn.drain_delta())
        assert not res.recompiled
        assert res.program is not None
    assert apply_patch_step._cache_size() - c0 <= 1, \
        "patch program compiled per distinct edit count instead of per bucket"
    _check_reads(eng, dyn, rng)


def test_sharded_dynamic_routes_and_realigns():
    from repro.distributed.eagr_shard import (
        ShardedDynamic,
        partition_overlay,
        shard_read_batch,
        shard_write_batch,
    )
    g = rmat_graph(150, 900, seed=9)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=2, seed=0)
    wf, rf = make_freqs(150, seed=9)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
    agg = make_aggregate("sum")
    spec = WindowSpec("tuple", 4)
    sharded = partition_overlay(ov, dec, n_shards=3, seed=1)
    engines = [EagrEngine(s, d, agg, spec, plan=p)
               for s, d, p in zip(sharded.shards, sharded.shard_decisions,
                                  sharded.shard_plans)]
    sd = ShardedDynamic(sharded, engines)
    ris = {r: set(s) for r, s in bp.reader_input_sets().items()}
    rng = np.random.default_rng(4)

    def write(ids, vals):
        for eng, (rows, v, m) in zip(engines,
                                     shard_write_batch(sharded, ids, vals)):
            eng.state = eng._write(eng.state, jnp.asarray(rows),
                                   jnp.asarray(v), jnp.asarray(m))
            eng._now_host += 1

    write(rng.choice(bp.writers, 48), rng.normal(size=48).astype(np.float32))
    for _ in range(10):
        r = int(rng.choice(list(ris)))
        w = int(rng.integers(0, 150))
        sd.add_edge(w, r)
        ris.setdefault(r, set()).add(w)
    results = sd.apply()
    assert any(res is not None for res in results)
    # aligned shards stay on ONE program shape even across a growth fallback
    assert len({p.meta for p in sharded.shard_plans}) == 1
    write(rng.choice(bp.writers, 48), rng.normal(size=48).astype(np.float32))
    readers = rng.choice(list(ris), 20)
    for eng, (nodes, m) in zip(engines, shard_read_batch(sharded, readers)):
        if not m.any():
            continue
        ans, _ = eng._read(eng.state, jnp.asarray(nodes), jnp.asarray(m))
        ans = np.asarray(ans)
        for i, r in enumerate(readers):  # batch-lane order: lane i <-> reader i
            if not m[i]:
                continue
            rows = eng.plan.writer_row_of_base
            want = eng.oracle_read(
                int(r), {int(r): {w for w in ris[int(r)] if w in rows}})
            np.testing.assert_allclose(np.ravel(ans[i]), np.ravel(want),
                                       rtol=1e-4, atol=1e-4)


# ------------------------------------------------------- property-based sweep
@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_property_patched_plan_stays_exact(seed):
    """Random add/delete edge/node sequences: after every step the patched
    plan's read_batch matches the window oracle, and — when capacity holds —
    the jit caches stay frozen."""
    eng, dyn, bp = _system(n=100, e=550, seed=seed % 7, headroom=2.5,
                           rng_seed=seed % 5)
    rng = np.random.default_rng(seed)
    readers = list(bp.reader_inputs)
    eng.write_batch(rng.choice(bp.writers, 12),
                    rng.normal(size=12).astype(np.float32), batch_size=12)
    dyn.add_edge(int(bp.writers[0]), int(readers[0]))
    eng.apply_delta(dyn.drain_delta())
    eng.write_batch(rng.choice(bp.writers, 12),
                    rng.normal(size=12).astype(np.float32), batch_size=12)
    _check_reads(eng, dyn, rng, k=4, batch=4)
    before = _cache_sizes()
    recompiles = 0
    for _ in range(12):
        _churn_step(dyn, rng, readers, n_base=100)
        recompiles += bool(eng.apply_delta(dyn.drain_delta()).recompiled)
        eng.write_batch(rng.choice(bp.writers, 12),
                        rng.normal(size=12).astype(np.float32), batch_size=12)
        _check_reads(eng, dyn, rng, k=4, batch=4)
    if recompiles == 0:
        assert _cache_sizes() == before, "in-capacity churn retraced"
