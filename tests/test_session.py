"""``EagrSession`` public-API parity: the declarative front door must be
BIT-identical to the hand-assembled low-level tier it wraps, across all three
deployment shapes (single engine, sharded-stacked, dynamic churn), and it
must inherit the substrate's trace/transfer discipline — session-driven
in-capacity churn stays on one ``apply_patch_step`` trace with zero implicit
host->device transfers (the harness from ``tests/test_device_patch.py``).

Plus the register-time validation surface: ``make_aggregate`` names the valid
aggregate set, ``Query.resolve`` rejects incompatible window/aggregate specs
before anything compiles, and engine groups are shared exactly when specs
are compatible.
"""
import jax
import numpy as np
import pytest

from conftest import make_freqs
from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.dynamic import DynamicOverlay
from repro.core.engine import EagrEngine
from repro.core.plan_patch import apply_patch_step
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph
from repro.session import EagrSession, Query, bucket_batch


def _hand_basis(g, *, max_iterations=3):
    """The session's internal construction, hand-assembled: adopt the
    constructed overlay into a ``DynamicOverlay`` and compile over the
    unpruned export (the §3.3-patchable id space)."""
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=max_iterations,
                          seed=0)
    dyn = DynamicOverlay.from_overlay(ov, bp.reader_input_sets())
    return bp, dyn, dyn.to_overlay(prune=False)


def _ones_decisions(basis, window=4, agg="sum"):
    n = max((o for o in basis.origin if o >= 0), default=0) + 1
    wf = np.ones(n)
    dec, _ = D.decide_mincut(basis, wf, wf.copy(),
                             D.cost_model_for(agg, window=window),
                             window=window)
    return dec


# ------------------------------------------------------------ single engine
def test_single_bit_identical_to_hand_assembled_engine():
    from repro.core.engine import _read_body, _write_body_sum

    g = rmat_graph(150, 900, seed=3)
    spec = WindowSpec("tuple", 4)

    bp, _, basis = _hand_basis(g)
    dec = _ones_decisions(basis)
    hand = EagrEngine(basis, dec, make_aggregate("sum"), spec, headroom=2.0)

    sess = EagrSession(g)
    h = sess.register(Query(agg="sum", window=spec))

    rng = np.random.default_rng(0)
    readers = np.asarray(sess.readers)
    caches = None
    for i in range(4):
        ids = rng.choice(bp.writers, 33)
        vals = rng.normal(size=33).astype(np.float32)
        hand.write_batch(ids, vals, batch_size=bucket_batch(33))
        sess.update(ids, vals)
        q = rng.choice(readers, 9)
        want = hand.read_batch(q, batch_size=bucket_batch(9))
        got = sess.read(h, q)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        if i == 0:
            # session and hand engine compile to the SAME plan shapes: once
            # the first round traced both bodies, neither side ever adds a
            # cache entry — the facade shares the hand path's programs
            caches = (_write_body_sum._cache_size(), _read_body._cache_size())
    assert caches == (_write_body_sum._cache_size(),
                      _read_body._cache_size()), \
        "session execution must reuse the hand-assembled compiled programs"


def test_single_extremal_time_window_matches_hand_assembled():
    g = rmat_graph(100, 550, seed=5)
    spec = WindowSpec("time", 6, capacity=8)

    bp, _, basis = _hand_basis(g)
    dec = _ones_decisions(basis, window=8, agg="max")
    hand = EagrEngine(basis, dec, make_aggregate("max"), spec, headroom=2.0)

    sess = EagrSession(g)
    h = sess.register(Query(agg="max", window=spec))
    rng = np.random.default_rng(1)
    readers = np.asarray(sess.readers)
    for _ in range(5):
        ids = rng.choice(bp.writers, 17)
        vals = rng.normal(size=17).astype(np.float32)
        hand.write_batch(ids, vals, batch_size=bucket_batch(17))
        sess.update(ids, vals)
        q = rng.choice(readers, 5)
        np.testing.assert_array_equal(
            np.asarray(sess.read(h, q)),
            np.asarray(hand.read_batch(q, batch_size=bucket_batch(5))))


# ---------------------------------------------------------- sharded stacked
def test_sharded_bit_identical_to_hand_assembled_stack():
    from repro.distributed.eagr_shard import partition_overlay
    from repro.distributed.stacked import StackedShardedEngine

    g = rmat_graph(150, 900, seed=3)
    spec = WindowSpec("tuple", 4)

    bp, _, basis = _hand_basis(g)
    dec = _ones_decisions(basis)
    sharded = partition_overlay(basis, dec, n_shards=4, seed=0, headroom=2.0)
    hand = StackedShardedEngine(sharded, make_aggregate("sum"), spec,
                                base_capacity=g.n_nodes)

    sess = EagrSession(g, shards=4)
    h = sess.register(Query(agg="sum", window=spec))

    rng = np.random.default_rng(2)
    readers = np.asarray(sess.readers)
    for _ in range(3):
        ids = rng.choice(bp.writers, 48)
        vals = rng.normal(size=48).astype(np.float32)
        hand.write_batch(ids, vals, batch_size=bucket_batch(48))
        sess.update(ids, vals)
        q = rng.choice(readers, 12)
        np.testing.assert_array_equal(
            np.asarray(sess.read(h, q)),
            np.asarray(hand.read_batch(q, batch_size=bucket_batch(12))))


# ------------------------------------------------------------ dynamic churn
def _churn(step_rng, mutate_both, readers, n_base):
    op = int(step_rng.integers(0, 4))
    if op == 0:
        mutate_both("add_edge", int(step_rng.integers(0, n_base)),
                    int(step_rng.choice(readers)))
    elif op == 1:
        mutate_both("delete_probe", int(step_rng.choice(readers)))
    elif op == 2:
        nid = int(step_rng.integers(1000, 2000))
        mutate_both("add_node", nid,
                    {int(x) for x in step_rng.integers(0, n_base, 3)},
                    {int(step_rng.choice(readers))})
    else:
        mutate_both("delete_new", None)


def test_dynamic_churn_bit_identical_to_hand_assembled():
    """Session-driven churn (mutate -> flush -> read) equals the hand path
    (DynamicOverlay journal -> drain_delta -> EagrEngine.apply_delta) bit for
    bit after every burst."""
    g = rmat_graph(120, 700, seed=3)
    spec = WindowSpec("tuple", 4)

    bp, hand_dyn, basis = _hand_basis(g)
    dec = _ones_decisions(basis)
    hand = EagrEngine(basis, dec, make_aggregate("sum"), spec, headroom=2.0)

    sess = EagrSession(g)
    h = sess.register(Query(agg="sum", window=spec))
    rng = np.random.default_rng(7)
    readers = list(hand_dyn.reader_inputs)

    def mutate_both(kind, *args):
        if kind == "add_edge":
            u, v = args
            hand_dyn.add_edge(u, v)
            sess.add_edge(u, v)
        elif kind == "delete_probe":
            (r,) = args
            if hand_dyn.reader_inputs.get(r):
                u = int(next(iter(hand_dyn.reader_inputs[r])))
                hand_dyn.delete_edge(u, r)
                sess.delete_edge(u, r)
        elif kind == "add_node":
            u, ins, outs = args
            hand_dyn.add_node(u, ins, outs)
            sess.add_node(u, ins, outs)
        else:
            victims = [k for k in list(hand_dyn.reader_inputs) if k >= 1000]
            if victims:
                u = int(rng.choice(victims))
                hand_dyn.delete_node(u)
                sess.delete_node(u)

    for _ in range(10):
        ids = rng.choice(bp.writers, 16)
        vals = rng.normal(size=16).astype(np.float32)
        hand.write_batch(ids, vals, batch_size=bucket_batch(16))
        sess.update(ids, vals)
        for _ in range(3):
            _churn(rng, mutate_both, readers, 120)
        hand.apply_delta(hand_dyn.drain_delta())
        sess.flush()
        pool = [r for r in hand_dyn.reader_inputs
                if hand_dyn.reader_inputs[r]
                and r in hand.plan.reader_node_of_base]
        q = rng.choice(pool, 6)
        np.testing.assert_array_equal(
            np.asarray(sess.read(h, q)),
            np.asarray(hand.read_batch(q, batch_size=bucket_batch(6))))


def test_sharded_churn_bit_identical_to_hand_assembled():
    from repro.distributed.eagr_shard import ShardedDynamic, partition_overlay
    from repro.distributed.stacked import StackedShardedEngine

    g = rmat_graph(150, 900, seed=3)
    spec = WindowSpec("tuple", 4)
    bp, _, basis = _hand_basis(g)
    dec = _ones_decisions(basis)
    sharded = partition_overlay(basis, dec, n_shards=2, seed=0, headroom=2.0)
    hand = StackedShardedEngine(sharded, make_aggregate("sum"), spec,
                                base_capacity=g.n_nodes)
    hand_sd = ShardedDynamic(sharded, hand)

    sess = EagrSession(g, shards=2)
    h = sess.register(Query(agg="sum", window=spec))
    rng = np.random.default_rng(4)
    readers = np.asarray(sess.readers)

    for _ in range(6):
        ids = rng.choice(bp.writers, 32)
        vals = rng.normal(size=32).astype(np.float32)
        hand.write_batch(ids, vals, batch_size=bucket_batch(32))
        sess.update(ids, vals)
        u, v = int(rng.integers(0, 150)), int(rng.choice(readers))
        hand_sd.add_edge(u, v)
        sess.add_edge(u, v)
        hand_sd.apply()
        sess.flush()
        q = rng.choice(readers, 8)
        np.testing.assert_array_equal(
            np.asarray(sess.read(h, q)),
            np.asarray(hand.read_batch(q, batch_size=bucket_batch(8))))


def test_session_churn_zero_uploads_and_one_patch_trace():
    """The PR-4 guarantees survive the facade: once the patch machinery is
    warm, session-driven in-capacity churn performs no implicit host->device
    transfer inside flush() and stays on one cached apply_patch_step
    executable (transfer-guard harness from tests/test_device_patch.py)."""
    g = rmat_graph(120, 700, seed=3)
    sess = EagrSession(g)
    h = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4)))
    rng = np.random.default_rng(5)
    readers = np.asarray(sess.readers)
    sess.update(rng.choice(sess.writers, 16),
                rng.normal(size=16).astype(np.float32))
    # warm every patch-path program once: slot claim, retire, node add with a
    # fresh writer row, node retire (window-row reset)
    sess.add_edge(int(sess.writers[0]), int(readers[0]))
    sess.flush()
    sess.delete_edge(int(sess.writers[0]), int(readers[0]))
    sess.flush()
    sess.add_node(1900, in_neighbors={int(sess.writers[0])},
                  out_readers={int(readers[0])})
    sess.flush()
    sess.delete_node(1900)
    sess.flush()

    c0 = apply_patch_step._cache_size()
    with jax.transfer_guard_host_to_device("disallow"):
        for _ in range(10):
            sess.add_edge(int(rng.integers(0, 120)), int(rng.choice(readers)))
            for res in sess.flush():
                assert res is None or not res.recompiled, \
                    "uniform churn exceeded headroom"
    assert apply_patch_step._cache_size() == c0, \
        "session churn must stay on the cached apply_patch_step traces"
    sess.update(rng.choice(sess.writers, 16),
                rng.normal(size=16).astype(np.float32))
    out = sess.read(h, rng.choice(readers, 6))
    assert np.isfinite(np.asarray(out)).all()


# ------------------------------------------------------- grouping + sharing
def test_compatible_queries_share_one_engine_group():
    g = rmat_graph(100, 550, seed=5)
    sess = EagrSession(g)
    a = sess.register(Query(agg="count", window=WindowSpec("tuple", 4)))
    b = sess.register(Query(agg="count", window=WindowSpec("tuple", 4),
                            readers=sess.readers[:3]))
    c = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4)))
    d = sess.register(Query(agg="count", window=WindowSpec("tuple", 8)))
    assert a.group is b.group and a.group.engine is b.group.engine
    assert c.group is not a.group and d.group is not a.group
    assert sess.n_engine_groups == 3
    # scoped handle rejects out-of-scope reads; unscoped sibling answers them
    outside = [r for r in sess.readers if r not in b.readers][:2]
    with pytest.raises(ValueError, match="readers scope"):
        sess.read(b, outside)
    sess.update(sess.writers[:8], np.ones(8, np.float32))
    np.testing.assert_array_equal(
        np.asarray(sess.read(b, sess.readers[:3])),
        np.asarray(sess.read(a, sess.readers[:3])))
    sess.unregister(b)
    assert sess.n_engine_groups == 3  # a still holds the group
    sess.unregister(a)
    assert sess.n_engine_groups == 2
    with pytest.raises(ValueError, match="unknown query handle"):
        sess.read(a, sess.readers[:1])


def test_adaptation_keeps_answers_exact():
    """adapt_every re-decides the frontier under observed traffic; answers
    must keep matching the window-level oracle across re-adoptions."""
    g = rmat_graph(150, 900, seed=3)
    sess = EagrSession(g, adapt_every=5)
    h = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4)))
    rng = np.random.default_rng(1)
    before = h.group.engine.plan.decision.copy()
    for _ in range(10):
        sess.update(rng.choice(sess.writers, 32),
                    rng.normal(size=32).astype(np.float32))
        sess.read(h, rng.choice(sess.readers, 16))
    after = h.group.engine.plan.decision
    n = min(len(before), len(after))
    assert (before[:n] != after[:n]).any(), "traffic skew produced no flip"
    sample = sess.readers[:5]
    out = sess.read(h, sample)
    for i, b in enumerate(sample):
        want = h.group.engine.oracle_read(int(b), sess._master.reader_inputs)
        np.testing.assert_allclose(np.ravel(out[i]), np.ravel(want),
                                   rtol=1e-4, atol=1e-4)


def test_continuous_query_pins_all_push():
    g = rmat_graph(100, 550, seed=5)
    wf, rf = make_freqs(100, seed=2)
    sess = EagrSession(g, write_freq=wf, read_freq=rf)
    cont = sess.register(Query(agg="count", continuous=True))
    opt = sess.register(Query(agg="count"))
    assert (cont.group.engine.plan.decision == D.PUSH).all()
    assert cont.group is not opt.group  # freshness class splits the group
    sess.update(sess.writers[:16], np.ones(16, np.float32))
    np.testing.assert_array_equal(
        np.asarray(sess.read(cont, sess.readers[:6])),
        np.asarray(sess.read(opt, sess.readers[:6])))


# ----------------------------------------------------------- validation API
def test_make_aggregate_names_valid_set():
    with pytest.raises(ValueError, match=r"unknown aggregate 'bogus'.*avg"):
        make_aggregate("bogus")
    with pytest.raises(ValueError, match=r"must be a string or Aggregate"):
        make_aggregate(3)
    with pytest.raises(ValueError, match=r"bad arguments for aggregate"):
        make_aggregate("sum", k=2)
    agg = make_aggregate("count")
    assert make_aggregate(agg) is agg
    with pytest.raises(ValueError, match="already constructed"):
        make_aggregate(agg, k=2)
    assert make_aggregate("TOP-K", k=2, domain=8).name == "topk"


@pytest.mark.parametrize("query,match", [
    (Query(agg="bogus"), r"unknown aggregate"),
    (Query(agg="count", window=WindowSpec("sliding", 4)), r"window kind"),
    (Query(agg="count", window=WindowSpec("time", 10)), r"ring capacity"),
    (Query(agg="count", window=WindowSpec("tuple", 0)), r"size must be >= 1"),
    (Query(agg="count", window=WindowSpec("tuple", 8, capacity=4)),
     r"cannot fit"),
    (Query(agg="topk", window=WindowSpec("tuple", 4, value_dim=3)),
     r"value_dim"),
    (Query(agg="sum", agg_kwargs={"value_dim": 3}), r"value_dim"),
    (Query(agg="count", readers=[]), r"readers is empty"),
])
def test_query_validation_rejects_at_register_time(query, match):
    with pytest.raises(ValueError, match=match):
        query.resolve()
    sess = EagrSession(build_bipartite(rmat_graph(40, 160, seed=1)))
    with pytest.raises(ValueError, match=match):
        sess.register(query)


def test_session_guards_write_stream_shape():
    g = rmat_graph(60, 260, seed=1)
    sess = EagrSession(g)
    h = sess.register(Query(agg="count"))
    with pytest.raises(ValueError, match="value_dim"):
        sess.register(Query(
            agg="sum", agg_kwargs={"value_dim": 2},
            window=WindowSpec("tuple", 4, value_dim=2)))
    with pytest.raises(ValueError, match="shape"):
        sess.update(sess.writers[:4], np.ones((4, 2), np.float32))
    with pytest.raises(ValueError, match="no queries registered"):
        EagrSession(g).update([0], np.ones(1, np.float32))
    # an emptied session stops constraining the write-value shape
    sess.unregister(h)
    h2 = sess.register(Query(agg="sum", agg_kwargs={"value_dim": 2},
                             window=WindowSpec("tuple", 4, value_dim=2)))
    sess.update(sess.writers[:4], np.ones((4, 2), np.float32))
    assert np.asarray(sess.read(h2, sess.readers[:2])).shape == (2, 2)


def test_custom_aggregate_declares_write_arity():
    """A user-defined vector aggregate registers through the front door by
    declaring Aggregate(value_dim=...) — the session is no narrower than the
    engine tier it fronts."""
    import jax.numpy as jnp

    from repro.core.aggregates import Aggregate

    l2 = Aggregate(name="sumsq", pao_dim=2, combine="sum",
                   lift=lambda v: (v.reshape(v.shape[0], -1) ** 2
                                   ).astype(jnp.float32),
                   finalize=lambda p: p, supports_subtraction=True,
                   value_dim=2)
    sess = EagrSession(rmat_graph(60, 260, seed=1))
    h = sess.register(Query(agg=l2, window=WindowSpec("tuple", 4,
                                                      value_dim=2)))
    sess.update(sess.writers[:8], np.full((8, 2), 2.0, np.float32))
    out = np.asarray(sess.read(h, sess.readers[:3]))
    assert out.shape == (3, 2) and (out >= 0).all()
    with pytest.raises(ValueError, match="value_dim"):
        Query(agg=l2).resolve()  # default scalar window can't feed it


def test_ingest_stats_alias_is_deprecated():
    """``session.ingest_stats`` still answers (a thin view of
    ``stats().ingest``) but warns — callers should migrate."""
    g = rmat_graph(60, 260, seed=1)
    sess = EagrSession(g, ingest_batch=16, ingest_depth=2)
    sess.register(Query(agg="sum"))
    sess.update(sess.writers[:8], np.ones(8, np.float32))
    with pytest.warns(DeprecationWarning, match=r"stats\(\).ingest"):
        alias = sess.ingest_stats
    assert alias is sess.stats().ingest
