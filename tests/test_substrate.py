"""Substrate: CSR graphs, 2-hop, neighbor sampler, sliding windows, sharding
rules, DIEN model pieces, dynamic overlay maintenance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bipartite import build_bipartite
from repro.core.dynamic import DynamicOverlay
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec, apply_writes, init_windows, window_pao
from repro.core.aggregates import make_aggregate
from repro.graphs.csr import CSRGraph
from repro.graphs.generators import rmat_graph
from repro.graphs.sampler import NeighborSampler
from repro.models.common import ParamSpec
from repro.distributed.sharding import DEFAULT_RULES, spec_for


# ------------------------------------------------------------------ graphs
def test_csr_roundtrip_and_reverse():
    src = np.array([0, 0, 1, 2, 3])
    dst = np.array([1, 2, 2, 0, 0])
    g = CSRGraph.from_edges(src, dst, 4)
    assert g.n_edges == 5
    assert set(g.out_neighbors(0).tolist()) == {1, 2}
    r = g.reverse()
    assert set(r.out_neighbors(2).tolist()) == {0, 1}
    s2, d2 = r.edge_list()
    g2 = CSRGraph.from_edges(d2, s2, 4)
    assert np.array_equal(g2.indptr, g.indptr)
    assert np.array_equal(np.sort(g2.indices), np.sort(g.indices))


def test_two_hop():
    g = CSRGraph.from_edges(np.array([0, 1, 2]), np.array([1, 2, 3]), 4)
    g2 = g.two_hop()
    assert set(g2.out_neighbors(0).tolist()) == {1, 2}
    assert set(g2.out_neighbors(1).tolist()) == {2, 3}


def test_bipartite_2hop_bigger_inputs():
    g = rmat_graph(100, 500, seed=1)
    b1 = build_bipartite(g, hops=1)
    b2 = build_bipartite(g, hops=2)
    common = set(b1.reader_inputs) & set(b2.reader_inputs)
    assert sum(b2.reader_inputs[r].size for r in common) >= \
        sum(b1.reader_inputs[r].size for r in common)


# ------------------------------------------------------------------ sampler
def test_neighbor_sampler_blocks():
    g = rmat_graph(500, 4000, seed=2)
    adj = g.reverse()
    sampler = NeighborSampler(adj, fanouts=(5, 3), seed=0)
    seeds = np.array([1, 2, 3, 4])
    blocks = sampler.sample(seeds)
    assert len(blocks) == 2
    seed_block = blocks[-1]
    assert np.array_equal(seed_block.dst_nodes, seeds)
    for blk in blocks:
        # every valid edge's source is a real in-neighbor of its destination
        for e in np.nonzero(blk.edge_mask)[0][:50]:
            s = blk.src_nodes[blk.edge_src[e]]
            d = blk.dst_nodes[blk.edge_dst[e]]
            assert s in adj.out_neighbors(int(d))


def test_sampler_fanout_cap():
    g = rmat_graph(300, 3000, seed=3)
    sampler = NeighborSampler(g.reverse(), fanouts=(7,), seed=1)
    blocks = sampler.sample(np.arange(16))
    blk = blocks[0]
    assert blk.edge_src.shape[0] == 16 * 7


# ------------------------------------------------------------------ windows
def test_tuple_window_semantics():
    spec = WindowSpec("tuple", 3)
    st_ = init_windows(2, spec)
    agg = make_aggregate("sum")
    rows = jnp.array([0, 0, 0, 0, 1], jnp.int32)
    vals = jnp.array([1., 2., 3., 4., 10.])
    st_, evicted, ev_valid = apply_writes(
        st_, spec, rows, vals, jnp.zeros(5), jnp.ones(5, bool))
    pao = np.asarray(window_pao(st_, spec, agg))
    assert pao[0, 0] == 2 + 3 + 4      # last 3 of writer 0
    assert pao[1, 0] == 10
    assert float(np.asarray(evicted)[3]) == 1.0 and bool(np.asarray(ev_valid)[3])


def test_time_window_semantics():
    spec = WindowSpec("time", size=5.0, capacity=8)
    st_ = init_windows(1, spec)
    agg = make_aggregate("sum")
    rows = jnp.zeros(4, jnp.int32)
    vals = jnp.array([1., 2., 4., 8.])
    stamps = jnp.array([0., 2., 6., 9.])
    st_, _, _ = apply_writes(st_, spec, rows, vals, stamps, jnp.ones(4, bool))
    # at t=10, window [5, 10] keeps stamps 6 and 9
    pao = np.asarray(window_pao(st_, spec, agg, now=10.0))
    assert pao[0, 0] == 12.0


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.floats(-10, 10)),
                min_size=1, max_size=40),
       st.integers(1, 5))
def test_property_tuple_window_matches_tail(writes, wsize):
    spec = WindowSpec("tuple", wsize)
    st_ = init_windows(4, spec)
    agg = make_aggregate("sum")
    rows = jnp.asarray([w[0] for w in writes], jnp.int32)
    vals = jnp.asarray([w[1] for w in writes], jnp.float32)
    st_, _, _ = apply_writes(st_, spec, rows, vals,
                             jnp.zeros(len(writes)), jnp.ones(len(writes), bool))
    pao = np.asarray(window_pao(st_, spec, agg))
    for w in range(4):
        tail = [v for r, v in writes if r == w][-wsize:]
        np.testing.assert_allclose(pao[w, 0], np.float32(sum(np.float32(t) for t in tail)),
                                   rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- sharding
def test_spec_for_divisibility_guard():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 1-device mesh: everything divisible, axes of size 1
    s = spec_for((8, 16), ("embed", "vocab"), mesh)
    assert len(s) == 2


def test_spec_for_drops_nondividing_axis():
    # simulate with a fake mesh via rules referencing missing axes
    mesh = jax.make_mesh((1,), ("data",))
    s = spec_for((7,), ("vocab",), mesh)   # 'model' missing entirely
    assert s == jax.sharding.PartitionSpec(None)


def test_param_spec_validation():
    with pytest.raises(AssertionError):
        ParamSpec((4, 4), ("embed",))


# ------------------------------------------------------------ dynamic churn
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 100))
def test_property_dynamic_churn_stays_exact(seed):
    rng = np.random.default_rng(seed)
    g = rmat_graph(120, 700, seed=seed % 5)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=2, seed=0)
    ris = bp.reader_input_sets()
    dyn = DynamicOverlay.from_overlay(ov, ris)
    readers = list(ris.keys())
    for _ in range(60):
        op = rng.integers(0, 4)
        if op == 0:
            r = int(rng.choice(readers))
            w = int(rng.integers(0, 120))
            dyn.add_edge(w, r)
        elif op == 1:
            r = int(rng.choice(readers))
            if dyn.reader_inputs.get(r):
                w = int(next(iter(dyn.reader_inputs[r])))
                dyn.delete_edge(w, r)
        elif op == 2:
            nid = int(rng.integers(1000, 2000))
            dyn.add_node(nid, in_neighbors={int(x) for x in rng.integers(0, 120, 3)},
                         out_readers={int(rng.choice(readers))})
        else:
            victims = [k for k in list(dyn.reader_inputs) if k >= 1000]
            if victims:
                dyn.delete_node(int(rng.choice(victims)))
    ov2 = dyn.to_overlay()
    ov2.validate({r: set(s) for r, s in dyn.reader_inputs.items() if s})


# --------------------------------------------------------------------- DIEN
def test_dien_profile_embed_is_embedding_bag():
    """profile_embed == jnp.take + masked mean (the EmbeddingBag contract)."""
    from repro.models.recsys.dien import DIENConfig, profile_embed
    cfg = DIENConfig(n_items=10, n_cats=4, n_profile_feats=20, seq_len=4)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(20, cfg.embed_dim)).astype(np.float32))
    params = {"profile_embed": table}
    ids = jnp.asarray(rng.integers(0, 20, (3, 5)).astype(np.int32))
    mask = jnp.asarray(rng.random((3, 5)) < 0.7)
    out = np.asarray(profile_embed(params, ids, mask, cfg))
    for b in range(3):
        sel = np.asarray(mask)[b]
        want = (np.asarray(table)[np.asarray(ids)[b]][sel].mean(axis=0)
                if sel.any() else np.zeros(cfg.embed_dim))
        np.testing.assert_allclose(out[b], want, rtol=1e-5, atol=1e-6)


def test_dien_augru_attention_scales_update():
    """With attention score 0 the AUGRU state must not move."""
    from repro.models.recsys.dien import DIENConfig, _augru_step, param_specs
    from repro.models.common import init_from_specs
    cfg = DIENConfig(n_items=10, n_cats=4, n_profile_feats=10, seq_len=4)
    p = init_from_specs(param_specs(cfg), jax.random.PRNGKey(0))
    h = jnp.ones((2, cfg.gru_dim))
    x = jnp.ones((2, cfg.gru_dim))
    h0 = _augru_step(p, x, h, jnp.zeros(2))
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h), atol=1e-6)
    h1 = _augru_step(p, x, h, jnp.ones(2))
    assert float(jnp.abs(h1 - h).max()) > 1e-3


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 5), st.integers(1, 5), st.integers(1, 25),
       st.integers(0, 10_000))
def test_property_vectorized_window_matches_scan(n_rows, cap, B, seed):
    """The vectorized ring append is event-at-a-time-equivalent (duplicates,
    wrap-around, masked lanes, pre-filled state)."""
    from repro.core.window import apply_writes, apply_writes_scan, live_mask
    rng = np.random.default_rng(seed)
    spec = WindowSpec("tuple", cap)
    st_ = init_windows(n_rows, spec)
    warm = rng.integers(0, n_rows, 7).astype(np.int32)
    st_, _, _ = apply_writes_scan(st_, spec, jnp.asarray(warm),
                                  jnp.asarray(rng.normal(size=7).astype(np.float32)),
                                  jnp.zeros(7), jnp.ones(7, bool))
    rows = jnp.asarray(rng.integers(0, n_rows, B).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=B).astype(np.float32))
    stamps = jnp.asarray(rng.normal(size=B).astype(np.float32))
    mask = jnp.asarray(rng.random(B) < 0.8)
    s1, e1, v1 = apply_writes_scan(st_, spec, rows, vals, stamps, mask)
    s2, e2, v2 = apply_writes(st_, spec, rows, vals, stamps, mask)
    assert np.array_equal(np.asarray(s1.head), np.asarray(s2.head))
    assert np.array_equal(np.asarray(s1.count), np.asarray(s2.count))
    assert np.array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2))
    lm1 = np.asarray(live_mask(s1, spec, 0.0))
    lm2 = np.asarray(live_mask(s2, spec, 0.0))
    assert np.array_equal(lm1, lm2)
    np.testing.assert_allclose(np.asarray(s1.values)[lm1],
                               np.asarray(s2.values)[lm1])
